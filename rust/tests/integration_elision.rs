//! Deterministic load-scenario tests for load-adaptive replica elision
//! (ISSUE 3; per-member control plane since ISSUE 5), driven by the same
//! stub backend + `FaultScript` harness as `integration_faults.rs` /
//! `integration_replication.rs`.
//!
//! Determinism: each "round" submits a known number of requests against a
//! known admission limit and drains every reply before the next round, so
//! the queue fill the batcher snapshots at batch close is exact — a round
//! of `max_batch` requests closes its batch on the final arrival with all
//! of its slots still admitted (fill = max_batch / capacity), and a round
//! of one request closes at the wait deadline with fill = 1 / capacity.
//! Per-member latency views are primary-host arrivals on the virtual
//! clock, so scripted stalls give exact per-member readings too. Pressure
//! readings, per-member mode transitions, elided standby compute/energy
//! and the (blended) admission limit are therefore all exactly
//! predictable.
//!
//! Acceptance criteria exercised here:
//! * a saturating load ramp walks every member Full → Partial → Elided
//!   (primaries only) in lockstep, and a drain walks them back — with
//!   per-member hysteresis, exact per-member mode ledgers, and the saved
//!   standby GFLOPS accounted exactly;
//! * **asymmetric elision** (ISSUE 5): when exactly one member ramps hot
//!   (a scripted within-deadline stall on its primary), that member — and
//!   only that member — reaches `Elided` while every cold member stays
//!   `Full`, under the stock queue/p95 signal, the `PredictiveSignal`
//!   (latency-predictor forecasts) and the `EnergyBudgetSignal`
//!   (joules-per-batch against per-member budgets) alike;
//! * admission-limit changes are smoothed: with `limit_blend < 1` a mode
//!   change mid-burst moves the limit exponentially toward the re-banked
//!   target, never in one step;
//! * primaries-only mode admits strictly more load (lower shed count) than
//!   always-replicate at equal configured capacity;
//! * a scripted primary crash during elision still aggregates at
//!   `min_quorum` with zero dropped batches, and the member is re-covered
//!   within one batch by warm-standby promotion;
//! * a degraded (not dead) primary instantly re-enables its standby under
//!   elision (the per-member fallback).

use std::collections::BTreeMap;
use std::time::Duration;

use coformer::config::{
    DeviceSpec, ElisionPolicy, FaultPolicy, MemberOverride, ReplicationPolicy, SystemConfig,
};
use coformer::coordinator::{
    Coordinator, CoordinatorHandle, EnergyBudgetSignal, InferenceResponse, Overloaded,
    PredictiveSignal, PressureSignal, RequestPayload, ServeBuilder,
};
use coformer::device::FaultScript;
use coformer::model::{Arch, CostModel, Mode};
use coformer::runtime::manifest::DeploymentMeta;
use coformer::runtime::{ExecServer, StubSpec};

const FLEET: usize = 4;
const CLASSES: usize = 4;

fn arch() -> Arch {
    Arch::uniform(Mode::Patch, 2, 16, 8, 1, 32, CLASSES)
}

fn x_stride() -> usize {
    let a = arch();
    a.tokens() * a.patch_dim() // 16 × 48
}

/// Start a 4-device coordinator (nano, tx2, orin-nano, rpi; central = tx2)
/// over the stub backend with the given scripts, policies and optional
/// custom pressure signal.
fn start_with_signal(
    scripts: Vec<FaultScript>,
    fault: FaultPolicy,
    replication: ReplicationPolicy,
    max_batch: usize,
    max_wait_ms: u64,
    signal: Option<Box<dyn PressureSignal>>,
) -> (ExecServer, Coordinator) {
    let members: Vec<String> = (0..FLEET).map(|i| format!("m{i}")).collect();
    let spec = StubSpec {
        models: members.iter().map(|m| (m.clone(), arch())).collect(),
        classes: CLASSES,
    };
    let server = ExecServer::start_stub(spec).unwrap();
    let dep = DeploymentMeta {
        task: "stub".into(),
        members,
        aggregators: BTreeMap::new(),
    };
    let mut config = SystemConfig::paper_default();
    config.devices.push(DeviceSpec::Preset("rpi-4b".into())); // 4th device
    config.deployment = "stub_4dev".into();
    config.aggregator = "average".into();
    config.max_batch = max_batch;
    config.max_wait_ms = max_wait_ms;
    let archs = vec![arch(); FLEET];
    let mut b = ServeBuilder::new(config, server.handle(), dep, archs, x_stride())
        .fault(fault)
        .replication(replication)
        .fault_scripts(scripts);
    if let Some(s) = signal {
        b = b.pressure_signal(s);
    }
    let coord = b.start().unwrap();
    (server, coord)
}

fn start(
    scripts: Vec<FaultScript>,
    fault: FaultPolicy,
    replication: ReplicationPolicy,
    max_batch: usize,
    max_wait_ms: u64,
) -> (ExecServer, Coordinator) {
    start_with_signal(scripts, fault, replication, max_batch, max_wait_ms, None)
}

fn no_fault_scripts() -> Vec<FaultScript> {
    (0..FLEET).map(|_| FaultScript::none()).collect()
}

/// The elastic policy under test: queue-only control (p95 gate off) so the
/// pressure sequence is exactly the submitted load.
fn elastic(high: f64, low: f64, hold: usize, shadow: usize) -> ElisionPolicy {
    ElisionPolicy {
        enabled: true,
        high_watermark: high,
        low_watermark: low,
        p95_high_ms: 0.0,
        hold_batches: hold,
        shadow_promoted_batches: shadow,
        ..ElisionPolicy::default()
    }
}

/// Submit `n` labeled requests pipelined (all admitted before any reply),
/// then drain every reply in order. One round == one deterministic
/// pressure reading == one batch when `n <= max_batch`.
fn round(handle: &CoordinatorHandle, n: usize) -> Vec<InferenceResponse> {
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let label = i % CLASSES;
            let rx = handle
                .submit(RequestPayload::F32(vec![label as f32; x_stride()]))
                .expect("round submits stay within the admission limit");
            (label, rx)
        })
        .collect();
    rxs.into_iter()
        .map(|(label, rx)| {
            let resp = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("reply must arrive")
                .expect("round batches must serve");
            assert_eq!(resp.prediction, label, "aggregation must stay correct");
            resp
        })
        .collect()
}

#[test]
fn load_ramp_elides_standbys_then_restores_them_after_drain() {
    // queue 8, rounds of 4 → fill 0.5 ≥ high 0.5 (saturation reading);
    // rounds of 1 → fill 0.125 ≤ low 0.3 (drain reading). The fill is
    // shared and every member runs the default thresholds, so all four
    // member machines step in lockstep: hold = 1 means one step per
    // reading — Partial, Elided, (hold), Partial, Full.
    let fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };
    let replication = ReplicationPolicy {
        replicas: 2,
        max_queue_depth: 8,
        elision: elastic(0.5, 0.3, 1, 0),
    };
    let (server, coord) = start(no_fault_scripts(), fault, replication, 4, 100);
    let handle = coord.handle();
    assert_eq!(handle.admission_state().limit, 8, "full fleet, Full mode: base limit");

    for _ in 0..3 {
        // saturation: r1 → Partial, r2 → Elided, r3 stays Elided
        for r in round(&handle, 4) {
            assert_eq!(r.quorum, FLEET, "healthy primaries keep full arity while elided");
        }
    }
    // primaries-only banks the standby budget: limit = 8 × (2n/n) = 16
    // (limit_blend defaults to 1: the full step applies immediately)
    assert_eq!(
        handle.admission_state().limit,
        16,
        "Elided mode re-banks saved standby GFLOPS as admission budget"
    );
    for _ in 0..3 {
        // drain: r4 → Partial, r5 → Full, r6 stays Full
        round(&handle, 1);
    }
    assert_eq!(handle.admission_state().limit, 8, "Full mode returns to the base limit");

    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_eq!(stats.batches, 6);
    assert_eq!(stats.requests, 15);
    assert_eq!(stats.fault.quorum_failures, 0);
    assert_eq!(stats.fault.degraded_batches(FLEET), 0);
    // exact fleet mode ledger: Partial (r1), Elided (r2, r3), Partial
    // (r4), Full (r5, r6) — and each of the 4 member machines made its
    // own 4 hysteresis-bounded transitions (the counter is the member sum)
    assert_eq!(stats.fault.batches_partial, 2);
    assert_eq!(stats.fault.batches_elided, 2);
    assert_eq!(stats.fault.batches_full, 2);
    assert_eq!(stats.fault.mode_transitions, 4 * FLEET);
    assert_eq!(stats.fault.member_modes.len(), FLEET);
    for (m, led) in stats.fault.member_modes.iter().enumerate() {
        assert_eq!(
            (led.full, led.partial, led.elided),
            (2, 2, 2),
            "member {m} lockstep ledger"
        );
        assert_eq!(led.transitions, 4, "member {m} transitions");
    }
    // saved standby compute is exact: 4 members × 1 live standby, skipped
    // for the 4 non-Full batches (rows 4, 4, 4 and 1)
    let expected_gflops =
        CostModel::flops_per_sample(&arch()) * FLEET as f64 * (4 + 4 + 4 + 1) as f64 / 1e9;
    assert!(
        (stats.fault.standby_gflops_saved - expected_gflops).abs() < 1e-9,
        "saved {} vs expected {expected_gflops}",
        stats.fault.standby_gflops_saved
    );
    let member_sum: f64 =
        stats.fault.member_modes.iter().map(|l| l.standby_gflops_saved).sum();
    assert!(
        (member_sum - stats.fault.standby_gflops_saved).abs() < 1e-9,
        "the per-member savings ledger sums to the fleet total"
    );
    assert!(stats.fault.standby_energy_saved_j > 0.0, "elided busy energy is accounted");
    assert_eq!(stats.fault.standby_fallbacks, 0, "no unhealthy primary, no fallback");
}

#[test]
fn hysteresis_holds_mode_through_alternating_load() {
    // hold = 2 with strictly alternating saturation/drain readings: no
    // member's streak ever reaches the hold, so no member may leave Full —
    // flapping load cannot flap any member's dispatch.
    let fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };
    let replication = ReplicationPolicy {
        replicas: 2,
        max_queue_depth: 8,
        elision: elastic(0.5, 0.3, 2, 0),
    };
    let (server, coord) = start(no_fault_scripts(), fault, replication, 4, 100);
    let handle = coord.handle();
    for _ in 0..4 {
        round(&handle, 4); // high reading
        round(&handle, 1); // low reading
    }
    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_eq!(stats.batches, 8);
    assert_eq!(stats.fault.mode_transitions, 0, "alternating load must not flap");
    assert_eq!(stats.fault.batches_full, 8);
    assert_eq!(stats.fault.batches_elided, 0);
    for led in &stats.fault.member_modes {
        assert_eq!((led.full, led.partial, led.elided, led.transitions), (8, 0, 0, 0));
    }
    assert_eq!(stats.fault.standby_gflops_saved, 0.0, "Full mode elides nothing");
}

/// The one-hot-member harness (ISSUE 5 acceptance): device 0 — member 0's
/// primary — stalls 5 virtual seconds on batches 1..=5, but a 30 s
/// deadline floor keeps every arrival on time, so the device stays
/// Healthy and the *only* asymmetry is member 0's own latency/energy
/// view. Six rounds of one request keep the shared queue fill at 0.125,
/// below every low watermark.
fn one_hot_scripts() -> Vec<FaultScript> {
    let mut scripts = no_fault_scripts();
    let mut s = FaultScript::none();
    for b in 1..=5 {
        s = s.and_stall_at(b, 5.0);
    }
    scripts[0] = s;
    scripts
}

fn one_hot_fault() -> FaultPolicy {
    FaultPolicy { min_quorum: 2, deadline_floor_s: 30.0, ..FaultPolicy::default() }
}

/// Assert the exact asymmetric ledger the one-hot scenario must produce
/// under any signal that keys on member 0's latency view: member 0 walks
/// Full(2) → Partial(1) → Elided(3) while every cold member stays Full.
fn assert_one_hot_ledger(stats: &coformer::coordinator::ServeStats) {
    assert_eq!(stats.batches, 6);
    assert_eq!(stats.fault.quorum_failures, 0, "the stalled primary is always on time");
    assert_eq!(stats.fault.timeouts, 0);
    assert_eq!(stats.fault.degraded_batches(FLEET), 0);
    let m0 = &stats.fault.member_modes[0];
    assert_eq!(
        (m0.full, m0.partial, m0.elided),
        (2, 1, 3),
        "the hot member ramps Full → Partial → Elided"
    );
    assert_eq!(m0.transitions, 2);
    for (m, led) in stats.fault.member_modes.iter().enumerate().skip(1) {
        assert_eq!(
            (led.full, led.partial, led.elided),
            (6, 0, 0),
            "cold member {m} must never leave Full"
        );
        assert_eq!(led.transitions, 0, "cold member {m} must not transition");
        assert_eq!(led.standby_gflops_saved, 0.0);
    }
    assert_eq!(stats.fault.mode_transitions, 2, "only the hot member moved");
    // fleet ledger keys on the most aggressive member mode
    assert_eq!(stats.fault.batches_full, 2);
    assert_eq!(stats.fault.batches_partial, 1);
    assert_eq!(stats.fault.batches_elided, 3);
    // exactly member 0's live standby was skipped, in batches 2..=5
    // (Partial already withdraws the healthy, unpromoted shadow), 1 row each
    let expected = CostModel::flops_per_sample(&arch()) * 4.0 / 1e9;
    assert!(
        (m0.standby_gflops_saved - expected).abs() < 1e-9,
        "member 0 saved {} vs expected {expected}",
        m0.standby_gflops_saved
    );
    assert!(
        (stats.fault.standby_gflops_saved - expected).abs() < 1e-9,
        "fleet savings are exactly the hot member's"
    );
    assert!(m0.standby_energy_saved_j > 0.0);
    assert_eq!(stats.fault.standby_fallbacks, 0, "a Healthy stalling primary is no fallback");
}

#[test]
fn one_hot_member_sheds_only_its_own_standby_under_the_default_signal() {
    // stock QueueP95Signal, per-member windows: member 0's p95 jumps to
    // ~5000 ms ≥ the 1000 ms gate after the first stalled batch lands in
    // its window; the cold members' windows stay at LAN-floor
    // milliseconds and the shared fill stays at 0.125.
    let mut elision = elastic(0.9, 0.5, 1, 0);
    elision.p95_high_ms = 1000.0;
    let replication = ReplicationPolicy { replicas: 2, max_queue_depth: 8, elision };
    let (server, coord) = start(one_hot_scripts(), one_hot_fault(), replication, 4, 100);
    let handle = coord.handle();
    for r in (0..6).flat_map(|_| round(&handle, 1)) {
        assert_eq!(r.quorum, FLEET, "elision never costs arity on a healthy fleet");
    }
    // one member elided out of four: headroom = 8f/7f, limit = ⌈8 × 8/7⌋ = 9
    assert_eq!(
        handle.admission_state().limit,
        9,
        "only the hot member's standby budget is re-banked"
    );
    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_one_hot_ledger(&stats);
}

#[test]
fn predictive_signal_drives_the_one_hot_member_through_mlp_forecasts() {
    // same scenario, pressure read by PredictiveSignal: baselines of 2 ms
    // (the healthy LAN-floor arrival), trend alpha 1. Member 0's stalled
    // arrival makes its one-step forecast ≈ 2 × 5002 − 2 ms, far past the
    // 1000 ms gate; the cold members' forecasts stay on baseline.
    let mut elision = elastic(0.9, 0.5, 1, 0);
    elision.p95_high_ms = 1000.0;
    let replication = ReplicationPolicy { replicas: 2, max_queue_depth: 8, elision };
    let signal = PredictiveSignal::from_baselines_ms(vec![2.0; FLEET], 1.0).unwrap();
    let (server, coord) = start_with_signal(
        one_hot_scripts(),
        one_hot_fault(),
        replication,
        4,
        100,
        Some(Box::new(signal)),
    );
    let handle = coord.handle();
    for _ in 0..6 {
        round(&handle, 1);
    }
    assert_eq!(handle.admission_state().limit, 9);
    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_one_hot_ledger(&stats);
}

#[test]
fn energy_budget_signal_elides_the_member_over_its_budget() {
    // no stalls: the asymmetry is purely configured energy budgets —
    // member 0's per-member override is microscopic (any measured joules
    // blow it), the fleet default is enormous (nobody else ever reads
    // hot). EnergyBudgetSignal turns each member's joules-per-batch into
    // fill against its own budget.
    let mut elision = elastic(0.75, 0.35, 1, 0);
    elision.energy_budget_j = 1e6;
    elision.member_overrides = vec![MemberOverride {
        member: 0,
        energy_budget_j: Some(1e-12),
        ..MemberOverride::default()
    }];
    let signal = EnergyBudgetSignal::from_policy(&elision, FLEET).unwrap();
    let replication = ReplicationPolicy { replicas: 2, max_queue_depth: 8, elision };
    let (server, coord) = start_with_signal(
        no_fault_scripts(),
        FaultPolicy { min_quorum: 2, ..FaultPolicy::default() },
        replication,
        4,
        100,
        Some(Box::new(signal)),
    );
    let handle = coord.handle();
    for _ in 0..5 {
        for r in round(&handle, 1) {
            assert_eq!(r.quorum, FLEET);
        }
    }
    assert_eq!(handle.admission_state().limit, 9, "the over-budget member banks its standby");
    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_eq!(stats.batches, 5);
    let m0 = &stats.fault.member_modes[0];
    // r1 reads empty energy windows (cold); r2 reads the first measured
    // joules → Partial; r3 → Elided; r4, r5 hold
    assert_eq!((m0.full, m0.partial, m0.elided), (1, 1, 3));
    assert_eq!(m0.transitions, 2);
    for led in stats.fault.member_modes.iter().skip(1) {
        assert_eq!((led.full, led.partial, led.elided, led.transitions), (5, 0, 0, 0));
    }
    assert_eq!(stats.fault.mode_transitions, 2);
    assert!(m0.standby_energy_saved_j > 0.0, "the skipped standby's joules are banked");
    assert!(stats.fault.standby_energy_saved_j > 0.0);
    assert_eq!(stats.fault.quorum_failures, 0);
}

#[test]
fn admission_limit_blends_exponentially_toward_the_elided_target() {
    // limit_blend 0.5 with a lockstep saturation ramp: once every member
    // is Elided the target limit is 16 (2× the base 8), and the live
    // limit must walk 8 → 12 → 14 → 15 → 16, halving the remaining gap
    // each batch — never the pre-ISSUE-5 single step.
    let fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };
    let mut elision = elastic(0.5, 0.3, 1, 0);
    elision.limit_blend = 0.5;
    let replication = ReplicationPolicy { replicas: 2, max_queue_depth: 8, elision };
    let (server, coord) = start(no_fault_scripts(), fault, replication, 4, 100);
    let handle = coord.handle();
    let mut limits = Vec::new();
    for _ in 0..6 {
        round(&handle, 4); // fill 0.5 ≥ high 0.5: saturation every round
        limits.push(handle.admission_state().limit);
    }
    // r1 steps everyone to Partial (target headroom 1 → limit holds);
    // r2 steps to Elided (target 16) and the blend takes over
    assert_eq!(limits, vec![8, 12, 14, 15, 16, 16]);
    // no single-batch step exceeds the configured blend of the gap
    let mut prev = 8usize;
    for &l in &limits {
        let step = l.abs_diff(prev);
        let gap = 16usize.abs_diff(prev);
        assert!(
            step * 2 <= gap + 1,
            "step {step} from {prev} exceeds blend 0.5 of gap {gap}"
        );
        prev = l;
    }
    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_eq!(stats.fault.mode_transitions, 2 * FLEET);
    assert_eq!(stats.fault.quorum_failures, 0);
}

#[test]
fn primary_crash_during_elision_meets_min_quorum_and_recovers_in_one_batch() {
    // Drive the fleet into primaries-only mode, then kill member 2's
    // primary (device 2) mid-stream. The crash batch runs at exactly
    // k = min_quorum = 3 — no batch errors, nothing dropped — and the warm
    // standby is promoted inside `mark_dead`, so the very next batch serves
    // the member again at full 4-of-4 arity (fallback within one batch).
    let mut scripts = no_fault_scripts();
    scripts[2] = FaultScript::crash_at(2); // r3 is batch index 2
    let fault = FaultPolicy { min_quorum: 3, ..FaultPolicy::default() };
    let replication = ReplicationPolicy {
        replicas: 2,
        max_queue_depth: 8,
        elision: elastic(0.5, 0.1, 1, 2),
    };
    let (server, coord) = start(scripts, fault, replication, 4, 100);
    let handle = coord.handle();

    round(&handle, 4); // r1: → Partial
    round(&handle, 4); // r2: → Elided
    let crash_batch = round(&handle, 4); // r3: Elided + primary crash
    for r in &crash_batch {
        assert_eq!(
            r.quorum, 3,
            "the elided member's slot is empty in the crash batch: exactly min_quorum"
        );
    }
    let after = round(&handle, 4); // r4: promoted standby serves as primary
    for r in &after {
        assert_eq!(r.quorum, FLEET, "promotion re-covers the member within one batch");
    }

    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_eq!(stats.batches, 4);
    assert_eq!(stats.fault.crashes, 1);
    assert_eq!(stats.fault.quorum_failures, 0, "zero dropped batches across the crash");
    assert_eq!(stats.fault.promotions, 1, "warm standby promoted, not cold re-dispatched");
    assert_eq!(stats.fault.redispatches, 0);
    assert_eq!(stats.fault.batches_at_quorum(3), 1);
    assert_eq!(stats.fault.batches_at_quorum(FLEET), 3);
    assert_eq!(stats.fault.degraded_batches(FLEET), 1, "only the crash batch ran short");
    assert!(stats.fault.batches_elided >= 2, "the crash really happened under elision");
}

#[test]
fn degraded_primary_reenables_its_standby_instantly_under_elision() {
    // A straggling (not dead) primary: device 3 stalls 5 virtual seconds in
    // r3, missing its deadline and walking to Degraded. In r4 — member 3
    // still in Elided mode — the per-member fallback must dispatch member
    // 3's standby again even though its machine says primaries-only.
    let mut scripts = no_fault_scripts();
    scripts[3] = FaultScript::stall_at(2, 5.0); // r3 is batch index 2
    let fault = FaultPolicy {
        min_quorum: 2,
        degraded_after: 1,
        dead_after: 10,
        recover_after: 2,
        ..FaultPolicy::default()
    };
    let replication = ReplicationPolicy {
        replicas: 2,
        max_queue_depth: 8,
        elision: elastic(0.5, 0.1, 1, 0),
    };
    let (server, coord) = start(scripts, fault, replication, 4, 100);
    let handle = coord.handle();

    round(&handle, 4); // r1: → Partial
    round(&handle, 4); // r2: → Elided
    let stalled = round(&handle, 4); // r3: straggler excluded, k = 3
    for r in &stalled {
        assert_eq!(r.quorum, 3, "the stalled primary's member is missing this batch");
    }
    let covered = round(&handle, 4); // r4: fallback re-runs the standby
    for r in &covered {
        assert_eq!(r.quorum, FLEET, "degraded member covered again at full arity");
    }

    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_eq!(stats.fault.timeouts, 1);
    assert_eq!(stats.fault.harvested_late, 1);
    assert_eq!(stats.fault.crashes, 0);
    assert!(
        stats.fault.standby_fallbacks >= 1,
        "the unhealthy-primary fallback must override primaries-only mode"
    );
    assert_eq!(stats.fault.quorum_failures, 0);
}

#[test]
fn elision_sheds_strictly_less_than_always_replicate_at_equal_capacity() {
    // The ISSUE 3 acceptance criterion. Identical fleet, identical
    // configured queue depth (8), identical workload: two saturation
    // rounds, then a burst of 24 submitted before any batch can close
    // (max_batch 64 ≫ burst, 300 ms coalesce window). Always-replicate
    // holds the base limit of 8 → sheds 16 of 24; elastic is in
    // primaries-only mode by the burst with the saved standby compute
    // re-banked (limit 16) → sheds only 8. Strictly more admitted
    // throughput, zero dropped batches in both runs.
    let run = |elision: ElisionPolicy| -> (usize, usize, coformer::coordinator::ServeStats) {
        let fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };
        let replication = ReplicationPolicy { replicas: 2, max_queue_depth: 8, elision };
        let (server, coord) = start(no_fault_scripts(), fault, replication, 64, 300);
        let handle = coord.handle();
        round(&handle, 4); // saturation reading 1 (fill 0.5)
        round(&handle, 4); // saturation reading 2
        let limit = handle.admission_state().limit;

        let mut admitted = Vec::new();
        let mut shed = 0usize;
        for i in 0..24usize {
            let label = i % CLASSES;
            match handle.submit(RequestPayload::F32(vec![label as f32; x_stride()])) {
                Ok(rx) => admitted.push((label, rx)),
                Err(e) => {
                    e.downcast_ref::<Overloaded>()
                        .expect("shed must carry the typed Overloaded error");
                    shed += 1;
                }
            }
        }
        for (label, rx) in admitted {
            let resp = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("admitted request must resolve")
                .expect("admitted request must succeed");
            assert_eq!(resp.prediction, label);
        }
        let stats = coord.shutdown().unwrap();
        drop(server);
        (limit, shed, stats)
    };

    let (limit_rep, shed_rep, stats_rep) = run(ElisionPolicy::default()); // disabled
    let (limit_eli, shed_eli, stats_eli) = run(elastic(0.5, 0.1, 1, 0));

    assert_eq!(limit_rep, 8, "always-replicate keeps the capacity-derived limit");
    assert_eq!(limit_eli, 16, "primaries-only banks the standby budget");
    assert_eq!(shed_rep, 16);
    assert_eq!(shed_eli, 8);
    assert!(
        shed_eli < shed_rep,
        "elision must shed strictly less at equal configured capacity"
    );
    assert_eq!(stats_rep.fault.shed, 16);
    assert_eq!(stats_eli.fault.shed, 8);
    assert!(
        stats_eli.requests > stats_rep.requests,
        "strictly higher admitted throughput: {} vs {}",
        stats_eli.requests,
        stats_rep.requests
    );
    assert_eq!(stats_rep.fault.quorum_failures, 0);
    assert_eq!(stats_eli.fault.quorum_failures, 0);
    assert!(stats_eli.fault.batches_elided >= 1);
    assert_eq!(stats_rep.fault.batches_elided, 0);
    assert!(stats_eli.fault.standby_gflops_saved > stats_rep.fault.standby_gflops_saved);
}
