//! Deterministic integration tests for replicated sub-models + admission
//! control (ISSUE 2), driven by the same stub backend + `FaultScript`
//! harness as `integration_faults.rs`.
//!
//! Acceptance criteria exercised here:
//! * with replication factor 2, a scripted primary crash mid-stream
//!   sustains full-arity (n-of-n) aggregation with zero quorum-size drop
//!   across the crash batch (the warm standby's output fills the slot in
//!   the very batch the primary dies), and the standby is *promoted* —
//!   not cold re-dispatched;
//! * an oversubscribed fleet sheds excess load with the typed
//!   [`Overloaded`] error while every admitted in-flight request still
//!   completes.

use std::collections::BTreeMap;
use std::time::Duration;

use coformer::config::{DeviceSpec, FaultPolicy, ReplicationPolicy, SystemConfig};
use coformer::coordinator::{
    serve_all, Coordinator, CoordinatorHandle, InferenceResponse, Overloaded,
    RequestPayload, ServeBuilder,
};
use coformer::device::FaultScript;
use coformer::model::{Arch, Mode};
use coformer::runtime::manifest::DeploymentMeta;
use coformer::runtime::{ExecServer, StubSpec};

const FLEET: usize = 4;
const CLASSES: usize = 4;

fn arch() -> Arch {
    Arch::uniform(Mode::Patch, 2, 16, 8, 1, 32, CLASSES)
}

fn x_stride() -> usize {
    let a = arch();
    a.tokens() * a.patch_dim() // 16 × 48
}

/// Start a 4-device coordinator (nano, tx2, orin-nano, rpi; central = tx2)
/// over the stub backend with the given scripts and policies.
fn start(
    scripts: Vec<FaultScript>,
    fault: FaultPolicy,
    replication: ReplicationPolicy,
    max_batch: usize,
    max_wait_ms: u64,
) -> (ExecServer, Coordinator) {
    let members: Vec<String> = (0..FLEET).map(|i| format!("m{i}")).collect();
    let spec = StubSpec {
        models: members.iter().map(|m| (m.clone(), arch())).collect(),
        classes: CLASSES,
    };
    let server = ExecServer::start_stub(spec).unwrap();
    let dep = DeploymentMeta {
        task: "stub".into(),
        members,
        aggregators: BTreeMap::new(),
    };
    let mut config = SystemConfig::paper_default();
    config.devices.push(DeviceSpec::Preset("rpi-4b".into())); // 4th device
    config.deployment = "stub_4dev".into();
    config.aggregator = "average".into();
    config.max_batch = max_batch;
    config.max_wait_ms = max_wait_ms;
    let archs = vec![arch(); FLEET];
    let coord = ServeBuilder::new(config, server.handle(), dep, archs, x_stride())
        .fault(fault)
        .replication(replication)
        .fault_scripts(scripts)
        .start()
        .unwrap();
    (server, coord)
}

/// Serve one pipelined round of labeled requests; row mean encodes the label.
fn round(
    handle: &CoordinatorHandle,
    labels: &[usize],
) -> coformer::Result<Vec<InferenceResponse>> {
    serve_all(
        handle,
        labels
            .iter()
            .map(|&l| RequestPayload::F32(vec![l as f32; x_stride()]))
            .collect(),
    )
}

fn no_fault_scripts() -> Vec<FaultScript> {
    (0..FLEET).map(|_| FaultScript::none()).collect()
}

#[test]
fn primary_crash_sustains_full_arity_with_warm_standby() {
    // Device 2's crash at batch 1 (mid-stream) kills member 2's primary;
    // with replication factor 2 the member's warm standby fills its slot in
    // the crash batch itself — the quorum histogram must show n-of-n for
    // EVERY batch, including the crash batch.
    let mut scripts = no_fault_scripts();
    scripts[2] = FaultScript::crash_at(1);
    let fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };
    let replication = ReplicationPolicy { replicas: 2, ..ReplicationPolicy::default() };
    let (server, coord) = start(scripts, fault, replication, 4, 2);
    let handle = coord.handle();
    let labels = [3usize, 1, 0, 2];
    for _ in 0..4 {
        let resp = round(&handle, &labels).unwrap();
        for (r, &l) in resp.iter().zip(&labels) {
            assert_eq!(r.prediction, l, "replicated aggregation must stay correct");
            assert_eq!(
                r.quorum, FLEET,
                "zero quorum-size drop: every batch aggregates n of n members"
            );
        }
    }
    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_eq!(stats.requests, 16);
    assert_eq!(stats.fault.crashes, 1);
    assert_eq!(stats.fault.quorum_failures, 0);
    assert_eq!(stats.fault.promotions, 1, "the warm standby was promoted");
    assert_eq!(
        stats.fault.redispatches, 0,
        "a member with a live replica must never cold re-dispatch"
    );
    assert!(
        stats.fault.replicas_placed >= 1,
        "the replication factor is restored on survivors"
    );
    assert!(
        stats.fault.replica_hits >= 1,
        "the crash batch's member-2 slot was filled by its replica"
    );
    // the headline: not a single degraded batch across the crash
    assert_eq!(stats.fault.degraded_batches(FLEET), 0);
    assert_eq!(stats.fault.batches_at_quorum(FLEET), stats.batches);
}

#[test]
fn unreplicated_crash_still_degrades_one_batch() {
    // Control: the identical crash with replicas = 1 drops the crash batch
    // to k = 3 (PR 1 behavior) — proving the zero-drop above comes from the
    // replica, not from the harness.
    let mut scripts = no_fault_scripts();
    scripts[2] = FaultScript::crash_at(1);
    let fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };
    let (server, coord) = start(scripts, fault, ReplicationPolicy::default(), 4, 2);
    let handle = coord.handle();
    let labels = [3usize, 1, 0, 2];
    for _ in 0..4 {
        let resp = round(&handle, &labels).unwrap();
        for (r, &l) in resp.iter().zip(&labels) {
            assert_eq!(r.prediction, l);
        }
    }
    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_eq!(stats.fault.crashes, 1);
    assert_eq!(stats.fault.promotions, 0);
    assert_eq!(stats.fault.redispatches, 1, "no replica → cold re-dispatch");
    assert_eq!(stats.fault.degraded_batches(FLEET), 1, "the crash batch ran at k=3");
    assert_eq!(stats.fault.batches_at_quorum(3), 1);
}

#[test]
fn oversubscribed_fleet_sheds_typed_overloaded_and_completes_in_flight() {
    // Admission limit 4 (full fleet). The batcher waits 400 ms before
    // shipping, so a burst of 8 submits admits the first 4 and must shed
    // the rest with a typed, downcastable Overloaded error — while the 4
    // admitted requests still complete correctly.
    let fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };
    let replication =
        ReplicationPolicy { replicas: 1, max_queue_depth: 4, ..ReplicationPolicy::default() };
    let (server, coord) = start(no_fault_scripts(), fault, replication, 64, 400);
    let handle = coord.handle();
    let limit = handle.admission_state().limit;
    assert_eq!(limit, 4, "full fleet alive: limit = configured depth");

    let mut admitted = Vec::new();
    let mut shed = 0usize;
    for i in 0..8usize {
        match handle.submit(RequestPayload::F32(vec![(i % CLASSES) as f32; x_stride()])) {
            Ok(rx) => admitted.push((i % CLASSES, rx)),
            Err(e) => {
                let o = e
                    .downcast_ref::<Overloaded>()
                    .expect("shed must carry the typed Overloaded error");
                assert_eq!(o.limit, 4);
                assert!(o.queued >= 4);
                assert!(e.to_string().contains("overloaded"), "{e}");
                shed += 1;
            }
        }
    }
    assert_eq!(admitted.len(), 4, "exactly the admission limit was admitted");
    assert_eq!(shed, 4, "the rest was shed");

    // every admitted request completes (shedding never cancels in-flight work)
    for (label, rx) in admitted {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("admitted request must resolve")
            .expect("admitted request must succeed");
        assert_eq!(resp.prediction, label);
    }
    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_eq!(stats.requests, 4);
    assert_eq!(stats.fault.shed, 4, "sheds are visible in the serve stats");

    // every admitted slot was released back to the gate when its reply went out
    assert_eq!(handle.admission_state().queued, 0);
}

#[test]
fn admission_limit_shrinks_with_surviving_capacity() {
    // Killing the Orin Nano (~41% of fleet effective GFLOPS) must shrink
    // the live admission limit proportionally: dead capacity takes its
    // queue budget with it.
    let mut scripts = no_fault_scripts();
    scripts[2] = FaultScript::crash_at(0);
    let fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };
    let replication =
        ReplicationPolicy { replicas: 1, max_queue_depth: 100, ..ReplicationPolicy::default() };
    let (server, coord) = start(scripts, fault, replication, 4, 2);
    let handle = coord.handle();
    assert_eq!(handle.admission_state().limit, 100);
    round(&handle, &[0, 1, 2, 3]).unwrap(); // crash observed in this round
    let limit = handle.admission_state().limit;
    assert!(
        limit < 100 && limit >= 1,
        "limit must shrink with the dead device's capacity share, got {limit}"
    );
    let stats = coord.shutdown().unwrap();
    drop(server);
    assert_eq!(stats.fault.crashes, 1);
}

#[test]
fn zero_min_quorum_rejected_at_start() {
    // ISSUE 2 regression: min_quorum = 0 must be rejected up front — at
    // k = 0 `renormalize_subset` produces all-zero features and the batch
    // would "aggregate" them into garbage predictions.
    let members: Vec<String> = (0..FLEET).map(|i| format!("m{i}")).collect();
    let spec = StubSpec {
        models: members.iter().map(|m| (m.clone(), arch())).collect(),
        classes: CLASSES,
    };
    let server = ExecServer::start_stub(spec).unwrap();
    let dep = DeploymentMeta { task: "stub".into(), members, aggregators: BTreeMap::new() };
    let mut config = SystemConfig::paper_default();
    config.devices.push(DeviceSpec::Preset("rpi-4b".into()));
    config.deployment = "stub_4dev".into();
    // bypass config-load validation: construct the policy directly — the
    // ServeBuilder path must reject it through SystemConfig::validate()
    config.fault = FaultPolicy { min_quorum: 0, ..FaultPolicy::default() };
    let err =
        ServeBuilder::new(config, server.handle(), dep, vec![arch(); FLEET], x_stride())
            .start()
            .err()
            .expect("min_quorum = 0 must be rejected");
    assert!(err.to_string().contains("min_quorum"), "{err}");
    drop(server);
}
