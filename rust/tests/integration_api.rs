//! ISSUE 4/5 acceptance suite for the unified public API:
//!
//! * `ServeBuilder` serving is deterministic — identical builds produce
//!   identical ledgers on the stub harness (the wrapper-delegation test
//!   retired with the deprecated `Coordinator::start*` entry points);
//! * `config::from_json` and `ServeBuilder::start` reject the same bad
//!   configs (both funnel through `SystemConfig::validate`), including
//!   the ISSUE 5 per-member override / blend / energy-budget fields;
//! * a custom per-member `PressureSignal` impl drops in through the trait
//!   and drives the elision ladder where the default signal would not;
//! * the sweep runner exercises the replicas/dispatch/member-elision axes
//!   end to end.

use std::collections::BTreeMap;
use std::time::Duration;

use coformer::config::{
    DeviceSpec, ElisionPolicy, FaultPolicy, MemberOverride, ReplicationPolicy, SystemConfig,
};
use coformer::coordinator::{
    Coordinator, CoordinatorHandle, EwmaLatencySignal, InferenceResponse, MemberPressure,
    PressureContext, PressureSignal, ServeBuilder, ServeStats,
};
use coformer::device::FaultScript;
use coformer::model::{Arch, Mode};
use coformer::runtime::manifest::DeploymentMeta;
use coformer::runtime::{ExecServer, StubSpec};
use coformer::strategies::registry::{CoFormer, CoFormerElastic};
use coformer::strategies::{DispatchMode, Scenario, Strategy, Sweep};
use coformer::util::Json;

const FLEET: usize = 4;
const CLASSES: usize = 4;

fn arch() -> Arch {
    Arch::uniform(Mode::Patch, 2, 16, 8, 1, 32, CLASSES)
}

fn x_stride() -> usize {
    let a = arch();
    a.tokens() * a.patch_dim()
}

fn stub_server() -> (ExecServer, DeploymentMeta) {
    let members: Vec<String> = (0..FLEET).map(|i| format!("m{i}")).collect();
    let spec = StubSpec {
        models: members.iter().map(|m| (m.clone(), arch())).collect(),
        classes: CLASSES,
    };
    let server = ExecServer::start_stub(spec).unwrap();
    let dep = DeploymentMeta { task: "stub".into(), members, aggregators: BTreeMap::new() };
    (server, dep)
}

fn base_config() -> SystemConfig {
    let mut config = SystemConfig::paper_default();
    config.devices.push(DeviceSpec::Preset("rpi-4b".into())); // 4th device
    config.deployment = "stub_4dev".into();
    config.aggregator = "average".into();
    config.max_batch = 4;
    config.max_wait_ms = 100;
    config
}

fn round(handle: &CoordinatorHandle, n: usize) -> Vec<InferenceResponse> {
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let label = i % CLASSES;
            let rx = handle
                .submit(coformer::coordinator::RequestPayload::F32(vec![
                    label as f32;
                    x_stride()
                ]))
                .expect("round submits stay within the admission limit");
            (label, rx)
        })
        .collect();
    rxs.into_iter()
        .map(|(label, rx)| {
            let resp = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("reply must arrive")
                .expect("round batches must serve");
            assert_eq!(resp.prediction, label);
            resp
        })
        .collect()
}

/// Serve three deterministic rounds through a coordinator and return its
/// final stats (quorums asserted inside `round`).
fn serve_rounds(coord: Coordinator) -> ServeStats {
    let handle = coord.handle();
    for _ in 0..3 {
        for r in round(&handle, 4) {
            assert!(r.quorum >= 3);
        }
    }
    coord.shutdown().unwrap()
}

#[test]
fn serve_builder_runs_are_deterministic_across_identical_builds() {
    // the positional Coordinator::start/start_with_faults wrappers are
    // gone (ISSUE 5); ServeBuilder is the one start path, and two
    // identical builds — same scripts, same policies — must produce the
    // identical deterministic serving ledger
    let mut scripts: Vec<FaultScript> = (0..FLEET).map(|_| FaultScript::none()).collect();
    scripts[2] = FaultScript::crash_at(1);
    let fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };
    let replication = ReplicationPolicy { replicas: 2, ..ReplicationPolicy::default() };

    let run = || {
        let (server, dep) = stub_server();
        let stats = serve_rounds(
            ServeBuilder::new(
                base_config(),
                server.handle(),
                dep,
                vec![arch(); FLEET],
                x_stride(),
            )
            .fault(fault)
            .replication(replication.clone())
            .fault_scripts(scripts.clone())
            .start()
            .unwrap(),
        );
        drop(server);
        stats
    };
    let a = run();
    let b = run();

    assert_eq!(a.requests, b.requests);
    assert_eq!(a.batches, b.batches);
    assert_eq!(a.fault.crashes, b.fault.crashes);
    assert_eq!(a.fault.promotions, b.fault.promotions);
    assert_eq!(a.fault.quorum_failures, b.fault.quorum_failures);
    assert_eq!(a.fault.quorum_histogram(), b.fault.quorum_histogram());
    assert_eq!(a.fault.crashes, 1, "the scripted crash really fired");
    assert_eq!(a.fault.promotions, 1, "the warm standby was promoted");
}

#[test]
fn json_and_serve_builder_reject_the_same_bad_configs() {
    // ISSUE 4 satellite: policy validation used to be duplicated between
    // config::from_json and coordinator startup; both now funnel through
    // SystemConfig::validate, so the same bad configs die on both paths
    // with the same diagnostic.
    let devices_json = r#"["jetson-nano","jetson-tx2","jetson-orin-nano","rpi-4b"]"#;
    let cases: Vec<(&str, Box<dyn Fn(&mut SystemConfig)>, &str)> = vec![
        (
            r#""fault":{"min_quorum":0}"#,
            Box::new(|c| c.fault.min_quorum = 0),
            "min_quorum",
        ),
        (
            r#""fault":{"min_quorum":9}"#,
            Box::new(|c| c.fault.min_quorum = 9),
            "unsatisfiable",
        ),
        (
            r#""fault":{"deadline_factor":0.5}"#,
            Box::new(|c| c.fault.deadline_factor = 0.5),
            "deadline_factor",
        ),
        (
            r#""replication":{"replicas":0}"#,
            Box::new(|c| c.replication.replicas = 0),
            "replicas",
        ),
        (
            r#""replication":{"replicas":9}"#,
            Box::new(|c| c.replication.replicas = 9),
            "replicas",
        ),
        (
            r#""replication":{"max_queue_depth":2000000}"#,
            Box::new(|c| c.replication.max_queue_depth = 2_000_000),
            "max_queue_depth",
        ),
        (
            r#""replication":{"elision":{"low_watermark":0.9,"high_watermark":0.5}}"#,
            Box::new(|c| {
                c.replication.elision.low_watermark = 0.9;
                c.replication.elision.high_watermark = 0.5;
            }),
            "low_watermark",
        ),
        (
            r#""replication":{"elision":{"hold_batches":0}}"#,
            Box::new(|c| c.replication.elision.hold_batches = 0),
            "hold_batches",
        ),
        (
            r#""replication":{"max_queue_depth":0,"elision":{"enabled":true}}"#,
            Box::new(|c| {
                c.replication.max_queue_depth = 0;
                c.replication.elision.enabled = true;
            }),
            "no pressure signal",
        ),
        (
            r#""replication":{"elision":{"member_overrides":[{"member":9}]}}"#,
            Box::new(|c| {
                c.replication.elision.member_overrides =
                    vec![MemberOverride { member: 9, ..MemberOverride::default() }];
            }),
            "member_overrides",
        ),
        (
            r#""replication":{"elision":{"limit_blend":0.0}}"#,
            Box::new(|c| c.replication.elision.limit_blend = 0.0),
            "limit_blend",
        ),
        (
            r#""replication":{"elision":{"energy_budget_j":-2.0}}"#,
            Box::new(|c| c.replication.elision.energy_budget_j = -2.0),
            "energy_budget_j",
        ),
        (r#""central":9"#, Box::new(|c| c.central = 9), "central"),
    ];

    let (server, dep) = stub_server();
    for (json_fragment, mutate, expect) in cases {
        // path 1: the JSON loader
        let json = format!(
            r#"{{"devices":{devices_json},"deployment":"stub_4dev",{json_fragment}}}"#
        );
        let json_err = SystemConfig::from_json(&Json::parse(&json).unwrap())
            .err()
            .unwrap_or_else(|| panic!("from_json must reject {json_fragment}"));
        assert!(
            json_err.to_string().contains(expect),
            "from_json({json_fragment}): {json_err}"
        );

        // path 2: a hand-built config through ServeBuilder::start
        let mut config = base_config();
        mutate(&mut config);
        let build_err = ServeBuilder::new(
            config,
            server.handle(),
            dep.clone(),
            vec![arch(); FLEET],
            x_stride(),
        )
        .start()
        .err()
        .unwrap_or_else(|| panic!("ServeBuilder must reject {json_fragment}"));
        assert!(
            build_err.to_string().contains(expect),
            "ServeBuilder({json_fragment}): {build_err}"
        );
    }
    drop(server);
}

#[test]
fn shape_mismatches_are_typed_errors_on_both_config_paths() {
    // ISSUE 8 satellite: the untyped `ensure!` length checks in
    // `ServeBuilder::start` became the typed `ShapeError` — the variant is
    // matchable through anyhow's downcast, the legacy diagnostic strings
    // are preserved verbatim, and a JSON-loaded config surfaces exactly
    // the same value as a hand-built one.
    use coformer::coordinator::ShapeError;

    let (server, dep) = stub_server();

    // fleet size vs deployment members — via a hand-built config
    let mut short = base_config();
    short.devices.pop(); // 3 devices against 4 members
    let err = ServeBuilder::new(short, server.handle(), dep.clone(), vec![arch(); FLEET], x_stride())
        .start()
        .err()
        .expect("3 devices against 4 members must be rejected");
    assert_eq!(
        err.downcast_ref::<ShapeError>(),
        Some(&ShapeError::DevicesVsMembers { devices: 3, members: FLEET })
    );
    assert_eq!(err.to_string(), "fleet size 3 != deployment members 4");

    // the same mismatch through the JSON loader: from_json accepts the
    // config (it cannot see the deployment), start raises the same value
    let json = r#"{"devices":["jetson-nano","jetson-tx2","jetson-orin-nano"],
                   "deployment":"stub_4dev","aggregator":"average"}"#;
    let from_json = SystemConfig::from_json(&Json::parse(json).unwrap()).unwrap();
    let json_err =
        ServeBuilder::new(from_json, server.handle(), dep.clone(), vec![arch(); FLEET], x_stride())
            .start()
            .err()
            .expect("the JSON-built config carries the same shape mismatch");
    assert_eq!(
        json_err.downcast_ref::<ShapeError>(),
        err.downcast_ref::<ShapeError>(),
        "JSON and builder paths surface the identical typed value"
    );
    assert_eq!(json_err.to_string(), err.to_string());

    // fault-script count vs fleet size
    let err = ServeBuilder::new(
        base_config(),
        server.handle(),
        dep.clone(),
        vec![arch(); FLEET],
        x_stride(),
    )
    .fault_scripts(vec![FaultScript::none(); 2])
    .start()
    .err()
    .expect("2 scripts against 4 devices must be rejected");
    assert_eq!(
        err.downcast_ref::<ShapeError>(),
        Some(&ShapeError::ScriptsVsDevices { scripts: 2, devices: FLEET })
    );
    assert_eq!(err.to_string(), "fault scripts 2 != fleet size 4");

    // arch count vs deployment members
    let err = ServeBuilder::new(
        base_config(),
        server.handle(),
        dep,
        vec![arch(); FLEET + 1],
        x_stride(),
    )
    .start()
    .err()
    .expect("5 archs against 4 members must be rejected");
    assert_eq!(
        err.downcast_ref::<ShapeError>(),
        Some(&ShapeError::ArchsVsMembers { archs: FLEET + 1, members: FLEET })
    );
    assert_eq!(err.to_string(), "arch count 5 != deployment members 4");
    drop(server);
}

/// A custom pressure signal: reads saturation for every member on every
/// batch regardless of the real queue. Plugged in through the trait, it
/// must walk every member to primaries-only where the default queue-fill
/// signal — fed the identical featherweight load — keeps full replication.
struct AlwaysHigh;

impl PressureSignal for AlwaysHigh {
    fn name(&self) -> &'static str {
        "always-high"
    }

    fn read(&mut self, ctx: &PressureContext<'_>) -> Vec<MemberPressure> {
        // deliberately ignore the real fill; keep the context used so the
        // shape of a real signal is exercised too
        let _ = ctx.intake.fill();
        ctx.members
            .iter()
            .map(|_| MemberPressure { fill: 1.0, latency_ms: 0.0 })
            .collect()
    }
}

#[test]
fn custom_pressure_signal_drives_elision_through_the_trait() {
    let elastic = ReplicationPolicy {
        replicas: 2,
        max_queue_depth: 8,
        elision: ElisionPolicy {
            enabled: true,
            high_watermark: 0.5,
            low_watermark: 0.3,
            p95_high_ms: 0.0,
            hold_batches: 1,
            shadow_promoted_batches: 0,
            ..ElisionPolicy::default()
        },
    };
    let fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };

    // featherweight load: rounds of 1 request → fill 0.125, below the low
    // watermark, so the default signal never reads High
    let run = |signal: Option<Box<dyn PressureSignal>>| {
        let (server, dep) = stub_server();
        let mut b = ServeBuilder::new(
            base_config(),
            server.handle(),
            dep,
            vec![arch(); FLEET],
            x_stride(),
        )
        .fault(fault)
        .replication(elastic.clone());
        if let Some(s) = signal {
            b = b.pressure_signal(s);
        }
        let coord = b.start().unwrap();
        let handle = coord.handle();
        for _ in 0..3 {
            round(&handle, 1);
        }
        let stats = coord.shutdown().unwrap();
        drop(server);
        stats
    };

    let default = run(None);
    assert_eq!(default.fault.batches_full, 3, "light load keeps Full under queue-fill");
    assert_eq!(default.fault.batches_elided, 0);
    assert_eq!(default.fault.mode_transitions, 0);

    let forced = run(Some(Box::new(AlwaysHigh)));
    assert_eq!(forced.fault.batches_full, 0, "the custom signal reads High from batch 1");
    assert_eq!(forced.fault.batches_partial, 1, "r1 steps Full → Partial");
    assert_eq!(forced.fault.batches_elided, 2, "r2 steps to Elided, r3 holds");
    assert_eq!(
        forced.fault.mode_transitions,
        2 * FLEET,
        "every member's machine walked Full → Partial → Elided"
    );
    assert!(forced.fault.standby_gflops_saved > 0.0);
    for (m, led) in forced.fault.member_modes.iter().enumerate() {
        assert_eq!((led.full, led.partial, led.elided), (0, 1, 2), "member {m} ledger");
        assert_eq!(led.transitions, 2, "member {m} transitions");
        assert!(led.standby_gflops_saved > 0.0, "member {m} banked its standby");
    }

    // a second stock impl through the same seam: the EWMA signal starts
    // and serves (its latency reading stays below any gate here)
    let ewma = run(Some(Box::new(EwmaLatencySignal::new(0.3).unwrap())));
    assert_eq!(ewma.requests, 3);
    assert_eq!(ewma.fault.quorum_failures, 0);
}

#[test]
fn custom_signal_permits_elision_without_stock_signals() {
    // shedding off + p95 gate off is rejected with the default signal
    // (the stock reading could never engage), but a custom signal supplies
    // its own reading — ServeBuilder must accept it and elision must run
    let replication = ReplicationPolicy {
        replicas: 2,
        max_queue_depth: 0,
        elision: ElisionPolicy {
            enabled: true,
            p95_high_ms: 0.0,
            hold_batches: 1,
            shadow_promoted_batches: 0,
            ..ElisionPolicy::default()
        },
    };
    let (server, dep) = stub_server();
    let err = ServeBuilder::new(
        base_config(),
        server.handle(),
        dep.clone(),
        vec![arch(); FLEET],
        x_stride(),
    )
    .replication(replication.clone())
    .start()
    .err()
    .expect("the default signal has nothing to read — must be rejected");
    assert!(err.to_string().contains("no pressure signal"), "{err}");

    let coord = ServeBuilder::new(
        base_config(),
        server.handle(),
        dep,
        vec![arch(); FLEET],
        x_stride(),
    )
    .replication(replication)
    .pressure_signal(Box::new(AlwaysHigh))
    .start()
    .unwrap();
    let handle = coord.handle();
    for _ in 0..3 {
        round(&handle, 1);
    }
    let stats = coord.shutdown().unwrap();
    drop(server);
    assert!(stats.fault.batches_elided >= 1, "the custom signal engaged elision");
    assert_eq!(stats.fault.quorum_failures, 0);
}

#[test]
fn sweep_replicas_and_dispatch_axes_score_the_redundancy_trade() {
    // replicas × dispatch through the sweep runner: Full dispatch with 2
    // replicas must cost strictly more energy than 1 replica, and Elided
    // must return to the single-copy timeline
    let sc = Scenario::builder()
        .fleet(coformer::device::DeviceProfile::paper_fleet())
        .topology(coformer::net::Topology::star(3, coformer::net::Link::mbps(100.0), 1))
        .archs(vec![arch(); 3])
        .d_i(64)
        .build()
        .unwrap();
    let points = Sweep::new(sc.clone())
        .replicas(&[1, 2])
        .dispatch_modes(&[DispatchMode::Full, DispatchMode::Elided])
        .run(&[&CoFormerElastic])
        .unwrap();
    assert_eq!(points.len(), 4);
    // order: (r1,Full), (r1,Elided), (r2,Full), (r2,Elided)
    let energy = |i: usize| points[i].outcome.total_energy_j();
    assert_eq!(energy(0), energy(1), "replicas=1: dispatch mode is irrelevant");
    assert!(energy(2) > energy(0), "full replication pays redundant energy");
    assert_eq!(
        points[3].outcome.replication.unwrap().copies_run,
        3,
        "elided returns to one live copy per member"
    );
    assert_eq!(points[2].outcome.replication.unwrap().copies_run, 6);
    // the healthy elided timeline is the plain aggregate-edge timeline
    let plain = CoFormer.run(&sc).unwrap();
    assert!((points[3].outcome.total_s() - plain.total_s()).abs() < 1e-15);
}

#[test]
fn sweep_member_elision_axis_scores_per_member_vs_fleet_wide() {
    // the ISSUE 5 axis: per-member masks against the fleet-wide extremes.
    // Eliding one member at a time banks exactly that member's standby
    // and lands strictly between always-replicate and fleet-wide elision.
    let sc = Scenario::builder()
        .fleet(coformer::device::DeviceProfile::paper_fleet())
        .topology(coformer::net::Topology::star(3, coformer::net::Link::mbps(100.0), 1))
        .archs(vec![arch(); 3])
        .d_i(64)
        .replicas(2)
        .build()
        .unwrap();
    let masks: Vec<Vec<bool>> = (0..3).map(|m| (0..3).map(|i| i == m).collect()).collect();
    let per_member = Sweep::new(sc.clone())
        .member_elision(&masks)
        .run(&[&CoFormerElastic])
        .unwrap();
    assert_eq!(per_member.len(), 3);
    let extremes = Sweep::new(sc)
        .dispatch_modes(&[DispatchMode::Full, DispatchMode::Elided])
        .run(&[&CoFormerElastic])
        .unwrap();
    let (full, elided) = (&extremes[0].outcome, &extremes[1].outcome);
    for (i, p) in per_member.iter().enumerate() {
        assert_eq!(p.elide_mask.as_deref(), Some(&masks[i][..]), "point carries its mask");
        let r = p.outcome.replication.unwrap();
        assert_eq!(r.copies_run, 5, "one member elides its standby, two keep theirs");
        assert_eq!(r.quorum, 3);
        assert!(p.outcome.total_energy_j() < full.total_energy_j());
        assert!(p.outcome.total_energy_j() > elided.total_energy_j());
        assert!(r.standby_gflops_saved > 0.0);
    }
}
