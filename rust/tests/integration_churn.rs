//! ISSUE 8 acceptance suite: runtime fleet churn with online DeBo
//! re-planning, locked down deterministically on the stub harness (no
//! artifacts, no PJRT client — every virtual quantity is model-derived,
//! so counters and energy ledgers are exactly reproducible):
//!
//! * an empty [`ChurnScript`] run is bitwise-identical to a fixed-fleet
//!   run — the churn plumbing must not perturb a single bit until the
//!   first real event;
//! * a scripted join warms up (shadow-executes) for exactly
//!   `ChurnPolicy::warmup_batches` batches without ever double-counting
//!   toward quorum;
//! * a scripted drain keeps serving until its members are re-covered,
//!   departs gracefully, and loses zero queued batches;
//! * a crashed slot re-enters via the `Rejoining` lifecycle (same slot,
//!   `rejoins` not `joins`);
//! * the staleness-triggered incremental re-plan fires exactly at
//!   `ChurnPolicy::staleness_threshold` — at the threshold it fires, one
//!   ulp above it stays quiet;
//! * the full churn story (join mid-ramp + drain + crash-rejoin) completes
//!   with zero dropped batches and ledgers sized to the live fleet;
//! * the sweep's churned-fleet axis scores what re-planning buys:
//!   `coformer_churn` beats `coformer_elastic` on the same churned
//!   scenario.

use std::collections::BTreeMap;
use std::time::Duration;

use coformer::config::{DeviceSpec, FaultPolicy, SystemConfig};
use coformer::coordinator::{
    ChurnScript, Coordinator, CoordinatorHandle, RequestPayload, ServeBuilder, ServeStats,
};
use coformer::device::{DeviceProfile, FaultScript};
use coformer::model::{Arch, CostModel, Mode};
use coformer::runtime::manifest::DeploymentMeta;
use coformer::runtime::{ExecServer, StubSpec};
use coformer::strategies::Sweep;

const FLEET: usize = 4;
const CLASSES: usize = 4;

fn arch() -> Arch {
    Arch::uniform(Mode::Patch, 2, 16, 8, 1, 32, CLASSES)
}

fn x_stride() -> usize {
    let a = arch();
    a.tokens() * a.patch_dim()
}

fn stub_server() -> (ExecServer, DeploymentMeta) {
    let members: Vec<String> = (0..FLEET).map(|i| format!("m{i}")).collect();
    let spec = StubSpec {
        models: members.iter().map(|m| (m.clone(), arch())).collect(),
        classes: CLASSES,
    };
    let server = ExecServer::start_stub(spec).unwrap();
    let dep = DeploymentMeta { task: "stub".into(), members, aggregators: BTreeMap::new() };
    (server, dep)
}

/// 4-device config mirroring the stub deployment; min_quorum 2 so a
/// mid-churn crash degrades instead of failing the batch.
fn base_config() -> SystemConfig {
    let mut config = SystemConfig::paper_default();
    config.devices.push(DeviceSpec::Preset("rpi-4b".into())); // 4th device
    config.deployment = "stub_4dev".into();
    config.aggregator = "average".into();
    config.max_batch = 4;
    config.max_wait_ms = 100;
    config.fault = FaultPolicy { min_quorum: 2, ..FaultPolicy::default() };
    config
}

/// One coalesced batch of `max_batch` requests; returns each reply's
/// quorum (asserting the prediction round-tripped and the reply arrived).
fn round(handle: &CoordinatorHandle, n: usize) -> Vec<usize> {
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let label = i % CLASSES;
            let rx = handle
                .submit(RequestPayload::F32(vec![label as f32; x_stride()]))
                .expect("round submits stay within the admission limit");
            (label, rx)
        })
        .collect();
    rxs.into_iter()
        .map(|(label, rx)| {
            let resp = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("reply must arrive")
                .expect("churned batches must keep serving");
            assert_eq!(resp.prediction, label);
            resp.quorum
        })
        .collect()
}

/// Serve `batches` rounds of 4 and return the final stats plus every
/// reply's quorum in arrival order.
fn serve(coord: Coordinator, batches: usize) -> (ServeStats, Vec<usize>) {
    let handle = coord.handle();
    let mut quorums = Vec::new();
    for _ in 0..batches {
        quorums.extend(round(&handle, 4));
    }
    (coord.shutdown().unwrap(), quorums)
}

fn build(config: SystemConfig, script: Option<ChurnScript>, faults: Vec<FaultScript>) -> (ExecServer, Coordinator) {
    let (server, dep) = stub_server();
    let mut b = ServeBuilder::new(config, server.handle(), dep, vec![arch(); FLEET], x_stride());
    if let Some(s) = script {
        b = b.churn_script(s);
    }
    if !faults.is_empty() {
        b = b.fault_scripts(faults);
    }
    (server, b.start().unwrap())
}

/// Field-by-field bitwise comparison of the deterministic parts of two
/// serving ledgers (wall-clock latency is the one non-virtual field and
/// is deliberately excluded).
fn assert_bitwise_identical(a: &ServeStats, b: &ServeStats) {
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.batches, b.batches);
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits(), "energy drifted");
    for p in [0.0, 50.0, 95.0, 100.0] {
        assert_eq!(
            a.virtual_latency.percentile_ms(p).to_bits(),
            b.virtual_latency.percentile_ms(p).to_bits(),
            "virtual latency p{p} drifted"
        );
    }
    let (fa, fb) = (&a.fault, &b.fault);
    assert_eq!(
        (fa.timeouts, fa.crashes, fa.exec_failures, fa.redispatches, fa.harvested_late),
        (fb.timeouts, fb.crashes, fb.exec_failures, fb.redispatches, fb.harvested_late)
    );
    assert_eq!(
        (fa.quorum_failures, fa.replica_hits, fa.promotions, fa.replicas_placed, fa.shed),
        (fb.quorum_failures, fb.replica_hits, fb.promotions, fb.replicas_placed, fb.shed)
    );
    assert_eq!(
        (fa.mode_transitions, fa.batches_full, fa.batches_partial, fa.batches_elided),
        (fb.mode_transitions, fb.batches_full, fb.batches_partial, fb.batches_elided)
    );
    assert_eq!(fa.standby_gflops_saved.to_bits(), fb.standby_gflops_saved.to_bits());
    assert_eq!(fa.standby_energy_saved_j.to_bits(), fb.standby_energy_saved_j.to_bits());
    assert_eq!(
        (fa.joins, fa.drains, fa.departs, fa.rejoins, fa.replans, fa.warming_excluded),
        (fb.joins, fb.drains, fb.departs, fb.rejoins, fb.replans, fb.warming_excluded)
    );
    assert_eq!(fa.quorum_histogram(), fb.quorum_histogram());
    assert_eq!(fa.member_modes.len(), fb.member_modes.len());
    for (la, lb) in fa.member_modes.iter().zip(&fb.member_modes) {
        assert_eq!((la.full, la.partial, la.elided, la.transitions), (lb.full, lb.partial, lb.elided, lb.transitions));
    }
}

/// An empty churn script must reproduce the fixed-fleet ledger bit for
/// bit, including through a scripted crash (whose `mark_dead` now also
/// writes the membership lifecycle — pure bookkeeping, observably inert).
#[test]
fn empty_churn_script_is_bitwise_identical_to_fixed_fleet() {
    let mut faults: Vec<FaultScript> = (0..FLEET).map(|_| FaultScript::none()).collect();
    faults[2] = FaultScript::crash_at(1);

    let run = |script: Option<ChurnScript>| {
        let (server, coord) = build(base_config(), script, faults.clone());
        let (stats, _) = serve(coord, 3);
        drop(server);
        stats
    };
    let fixed = run(None);
    let churn_plumbed = run(Some(ChurnScript::none()));

    assert_eq!(fixed.fault.crashes, 1, "the scripted crash really fired");
    assert_eq!(fixed.fault.joins + fixed.fault.drains + fixed.fault.rejoins, 0);
    assert_bitwise_identical(&fixed, &churn_plumbed);
}

/// A scripted join shadow-executes for exactly `warmup_batches` batches
/// (each delivery counted in `warming_excluded`) and never double-counts
/// toward quorum: every batch aggregates exactly the 4 deployment members.
#[test]
fn join_warms_up_without_double_counting_quorum() {
    let config = base_config();
    let warmup = config.churn.warmup_batches;
    let (server, coord) = build(
        config,
        Some(ChurnScript::join_at(1, DeviceProfile::rpi4())),
        Vec::new(),
    );
    let (stats, quorums) = serve(coord, 5);
    drop(server);

    assert_eq!(stats.requests, 20);
    assert_eq!(stats.batches, 5);
    assert_eq!(stats.fault.joins, 1, "the scripted join admitted one device");
    assert_eq!(stats.fault.rejoins, 0);
    assert_eq!(stats.fault.crashes, 0);
    assert_eq!(stats.fault.quorum_failures, 0);
    assert_eq!(
        stats.fault.warming_excluded, warmup,
        "the joiner shadow-delivered once per warm-up batch, and was excluded each time"
    );
    // quorum is member-arity: the joiner serves as a 5th device but can
    // only ever fill one of the 4 member slots, warmed up or not
    assert!(quorums.iter().all(|&q| q == FLEET), "quorums: {quorums:?}");
    for (k, &count) in stats.fault.quorum_histogram().iter().enumerate() {
        assert!(count == 0 || k == FLEET, "histogram leaked a non-{FLEET} quorum at {k}");
    }
}

/// A scripted drain places cover for its solo-hosted members, keeps the
/// draining device serving until the cover is live, then departs it
/// through the promotion machinery — zero queued batches lost, no crash
/// recorded.
#[test]
fn drain_serves_until_covered_and_loses_no_batches() {
    let (server, coord) =
        build(base_config(), Some(ChurnScript::drain_at(1, 0)), Vec::new());
    let (stats, quorums) = serve(coord, 5);
    drop(server);

    assert_eq!(stats.requests, 20, "every queued request was served");
    assert_eq!(stats.fault.drains, 1);
    assert_eq!(stats.fault.departs, 1, "the drain completed as a graceful departure");
    assert_eq!(stats.fault.crashes, 0, "a drain is not a crash");
    assert_eq!(stats.fault.timeouts, 0);
    assert_eq!(stats.fault.quorum_failures, 0);
    assert_eq!(
        stats.fault.replicas_placed, 1,
        "the drained device's member got exactly one cover standby"
    );
    assert_eq!(
        stats.fault.promotions, 1,
        "departure promoted the warm cover, the same path a fault takes"
    );
    assert!(quorums.iter().all(|&q| q == FLEET), "no member slot went dark: {quorums:?}");
}

/// A crashed slot re-enters via `Rejoining`: same slot index, a fresh
/// warm-up, counted in `rejoins` — never as a fresh `joins` slot.
#[test]
fn crash_rejoin_reenters_the_same_slot_with_a_fresh_warmup() {
    let config = base_config();
    let warmup = config.churn.warmup_batches;
    let mut faults: Vec<FaultScript> = (0..FLEET).map(|_| FaultScript::none()).collect();
    faults[2] = FaultScript::crash_at(1);
    let (server, coord) = build(
        config,
        Some(ChurnScript::none().and_rejoin_at(3, 2)),
        faults,
    );
    let (stats, quorums) = serve(coord, 6);
    drop(server);

    assert_eq!(stats.fault.crashes, 1, "the scripted crash fired");
    assert_eq!(stats.fault.redispatches, 1, "the crashed member cold-redispatched");
    assert_eq!(stats.fault.rejoins, 1, "the slot re-entered via Rejoining");
    assert_eq!(stats.fault.joins, 0, "a rejoin is not a fresh join slot");
    assert_eq!(
        stats.fault.warming_excluded, warmup,
        "the rejoiner re-ran the full warm-up before counting again"
    );
    assert_eq!(stats.fault.quorum_failures, 0);
    // the crash batch itself degrades to 3 of 4; everything else is full
    assert_eq!(quorums.iter().filter(|&&q| q == FLEET - 1).count(), 4);
    assert_eq!(quorums.iter().filter(|&&q| q == FLEET).count(), 20);
}

/// The incremental re-plan fires exactly at the staleness threshold: with
/// the threshold set to the drained device's precise capacity share it
/// fires once (at the batch the capacity actually drops — departure, not
/// drain start), and one part in 10^9 above that share it never fires.
#[test]
fn replan_triggers_exactly_at_the_staleness_threshold() {
    // the same prefix-sum order the leader uses, so the bits match
    let profiles = [
        DeviceProfile::jetson_nano(),
        DeviceProfile::jetson_tx2(),
        DeviceProfile::jetson_orin_nano(),
        DeviceProfile::rpi4(),
    ];
    let planned: f64 = profiles.iter().map(|d| d.effective_gflops()).sum();
    let live: f64 = profiles[..3].iter().map(|d| d.effective_gflops()).sum();
    let staleness = (live - planned).abs() / planned;

    let run = |threshold: f64| {
        let mut config = base_config();
        config.churn.enabled = true;
        config.churn.staleness_threshold = threshold;
        let (server, coord) =
            build(config, Some(ChurnScript::drain_at(1, 3)), Vec::new());
        let (stats, quorums) = serve(coord, 5);
        drop(server);
        assert!(quorums.iter().all(|&q| q == FLEET), "re-planning must not drop members");
        assert_eq!(stats.fault.drains, 1);
        assert_eq!(stats.fault.departs, 1);
        stats
    };

    let at = run(staleness);
    assert_eq!(
        at.fault.replans, 1,
        "staleness == threshold fires the re-plan, exactly once (the marker advances)"
    );
    let above = run(staleness * (1.0 + 1e-9));
    assert_eq!(above.fault.replans, 0, "one part in 10^9 above the drift stays quiet");
}

/// The full churn story from the issue: a join mid-ramp, a drain, and a
/// crash-rejoin, in one scripted run — zero dropped batches, every
/// lifecycle counter accounted, ledgers still sized to the deployment.
#[test]
fn scripted_join_drain_and_crash_rejoin_complete_with_zero_dropped_batches() {
    let config = base_config();
    let warmup = config.churn.warmup_batches;
    let mut faults: Vec<FaultScript> = (0..FLEET).map(|_| FaultScript::none()).collect();
    faults[2] = FaultScript::crash_at(2);
    let script = ChurnScript::join_at(1, DeviceProfile::rpi4())
        .and_drain_at(3, 0)
        .and_rejoin_at(6, 0);
    let (server, coord) = build(config, Some(script), faults);
    let (stats, quorums) = serve(coord, 8);
    drop(server);

    assert_eq!(stats.requests, 32, "zero dropped batches across the whole churn story");
    assert_eq!(stats.batches, 8);
    assert_eq!(stats.fault.joins, 1);
    assert_eq!(stats.fault.drains, 1);
    assert_eq!(stats.fault.departs, 1);
    assert_eq!(stats.fault.crashes, 1);
    assert_eq!(stats.fault.rejoins, 1);
    assert_eq!(stats.fault.quorum_failures, 0);
    // joiner + rejoiner each shadow-execute a full warm-up
    assert_eq!(stats.fault.warming_excluded, 2 * warmup);
    // only the crash batch degrades; drains and rejoins never cost a member
    assert_eq!(quorums.iter().filter(|&&q| q == FLEET - 1).count(), 4);
    assert_eq!(quorums.iter().filter(|&&q| q == FLEET).count(), 28);
    // ledgers stay member-indexed (the fleet grew to 5 slots, members are 4)
    assert_eq!(stats.fault.member_modes.len(), FLEET);
}

/// The sweep's churned-fleet axis (ISSUE 8): `coformer_churn` re-ranks the
/// decomposition onto the serving fleet, `coformer_elastic` serves the
/// stale mapping — on a fleet whose fastest device churned away from the
/// heaviest member, the re-plan measurably wins the Sweep-scored latency.
#[test]
fn sweep_churned_fleet_axis_scores_what_replanning_buys() {
    let heavy = Arch::uniform(Mode::Patch, 2, 32, 8, 2, 64, CLASSES);
    let light = Arch::uniform(Mode::Patch, 2, 8, 8, 1, 16, CLASSES);
    assert!(
        CostModel::flops_per_sample(&heavy) > CostModel::flops_per_sample(&light),
        "the heavy member must dominate the timeline"
    );
    // planned: the heavy member 0 on the fastest device (TX2)
    let planned = vec![
        DeviceProfile::jetson_tx2(),
        DeviceProfile::jetson_orin_nano(),
        DeviceProfile::jetson_nano(),
    ];
    // churned: the TX2 left and a Nano took slot 0 — the heavy member now
    // serves on the slowest device unless someone re-plans
    let churned = vec![
        DeviceProfile::jetson_nano(),
        DeviceProfile::jetson_orin_nano(),
        DeviceProfile::jetson_tx2(),
    ];
    let base = coformer::strategies::Scenario::builder()
        .fleet(planned)
        .topology(coformer::net::Topology::star(3, coformer::net::Link::mbps(100.0), 1))
        .archs(vec![heavy, light.clone(), light])
        .d_i(64)
        .build()
        .unwrap();

    let points = Sweep::new(base.clone())
        .churned_fleets(&[churned.clone()])
        .run_named(&["coformer_elastic", "coformer_churn"])
        .unwrap();
    assert_eq!(points.len(), 2);
    let (stale, replanned) = (&points[0], &points[1]);
    assert_eq!(stale.strategy, "coformer_elastic");
    assert_eq!(replanned.strategy, "coformer_churn");
    assert_eq!(stale.churned_fleet.as_deref(), Some(&churned[..]), "the point carries its axis");
    assert!(
        replanned.outcome.total_s() < stale.outcome.total_s(),
        "re-planning must beat the stale decomposition: {} vs {}",
        replanned.outcome.total_s(),
        stale.outcome.total_s()
    );

    // and the stale churned serve really is a regression vs the plan the
    // members were sized for — the gap the re-planner closes
    let on_plan = Sweep::new(base).run_named(&["coformer_elastic"]).unwrap();
    assert!(stale.outcome.total_s() > on_plan[0].outcome.total_s());
}
