//! Runtime (PJRT) hot-path benches: sub-model forward at batch 1 and 16,
//! aggregator execution, masked-teacher execution, and parameter upload.
//! These are the numbers behind the end-to-end serving latency — requires
//! `make artifacts`.

use coformer::data::Dataset;
use coformer::metrics::bench::{bench, black_box};
use coformer::runtime::engine::XBatch;
use coformer::runtime::Engine;

fn main() {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("bench runtime: SKIPPED (run `make artifacts` first)");
        // gated suites appear in BENCH_*.json as skipped, never silently absent
        coformer::metrics::bench::skip_marker("runtime_suite", "artifacts not built");
        return;
    }
    println!("== bench: PJRT runtime ==");
    let engine = Engine::load(artifacts).expect("engine");
    let m = engine.manifest().clone();
    let task = m.task("edgenet").expect("task").clone();
    let ds = Dataset::load(artifacts, &task.splits["test"]).expect("dataset");

    let members = ["edgenet_tiny24", "edgenet_small32", "edgenet_med40"];
    // warm compile everything first (deployment-time cost, not serving cost)
    let t0 = std::time::Instant::now();
    for name in members.iter().chain(["teacher_edgenet"].iter()) {
        let meta = m.model(name).unwrap().clone();
        for hlo in meta.hlo.values() {
            engine.executable(hlo).unwrap();
        }
        engine.model_param_literals(name).unwrap();
    }
    println!("one-time compile+upload: {:.2} s", t0.elapsed().as_secs_f64());

    let batch_of = |n: usize| {
        let idx: Vec<usize> = (0..n).collect();
        let mut shape = ds.x_shape.clone();
        shape[0] = n;
        XBatch::F32 { data: ds.gather_x_f32(&idx), shape }
    };

    for name in members {
        let x1 = batch_of(1);
        bench(&format!("{name}_fwd_b1"), 20, 300, || {
            black_box(engine.run_model(name, &x1).unwrap().logits.len());
        });
        let x16 = batch_of(16);
        bench(&format!("{name}_fwd_b16"), 10, 150, || {
            black_box(engine.run_model(name, &x16).unwrap().logits.len());
        });
    }
    {
        let x16 = batch_of(16);
        bench("teacher_edgenet_fwd_b16", 10, 100, || {
            black_box(engine.run_model("teacher_edgenet", &x16).unwrap().logits.len());
        });
    }

    // aggregator (Phase 3)
    let x16 = batch_of(16);
    let feats: Vec<(Vec<f32>, Vec<usize>)> = members
        .iter()
        .map(|name| {
            let o = engine.run_model(name, &x16).unwrap();
            (o.feats, o.feats_shape)
        })
        .collect();
    bench("aggregator_mlp_b16", 20, 300, || {
        black_box(
            engine
                .run_aggregator("edgenet_3dev", "mlp", &feats)
                .unwrap()
                .0
                .len(),
        );
    });

    // masked teacher (Fig 5 path)
    let mask = vec![1.0f32; 16];
    bench("masked_teacher_b16", 5, 60, || {
        black_box(
            engine
                .run_masked("teacher_edgenet_masked", &x16, &mask)
                .unwrap()
                .logits
                .len(),
        );
    });

    // parameter upload cost (deployment path)
    let meta = m.model("edgenet_med40").unwrap().clone();
    bench("param_load_med40", 3, 30, || {
        black_box(
            engine
                .load_param_literals(&meta.params, &meta.param_specs)
                .unwrap()
                .len(),
        );
    });
}
