//! Strategy-simulation benches: one per paper table/figure family. These
//! are the generators behind Figs 3/4/10/12 and Tables III/V — each bench
//! measures regenerating one full figure's data points.

use coformer::device::DeviceProfile;
use coformer::metrics::bench::{bench, black_box};
use coformer::model::{Arch, CostModel, Mode, SubModelCfg};
use coformer::net::{Link, Topology};
use coformer::strategies::{self, Segment};

fn deit_b() -> Arch {
    let mut a = Arch::uniform(Mode::Patch, 12, 768, 64, 12, 3072, 1000);
    a.img_size = 224;
    a.patch_size = 16;
    a
}

fn subs() -> Vec<Arch> {
    let t = deit_b();
    vec![
        SubModelCfg { layers: 6, dim: 192, heads: 3, mlp_dim: 768 }.to_arch(&t),
        SubModelCfg { layers: 8, dim: 256, heads: 4, mlp_dim: 1024 }.to_arch(&t),
        SubModelCfg { layers: 10, dim: 320, heads: 5, mlp_dim: 1280 }.to_arch(&t),
    ]
}

fn main() {
    println!("== bench: strategies (figure generators) ==");
    let fleet = DeviceProfile::paper_fleet();
    let topo = Topology::star(3, Link::mbps(100.0), 1);
    let s = subs();
    let t_flops = CostModel::flops_per_sample(&deit_b());

    bench("coformer_step (fig9/10/12 rows)", 10, 1000, || {
        black_box(strategies::coformer(&fleet, &topo, &s, 512, 1).unwrap().total_s);
    });

    let seg = |l: f64| Segment {
        flops: t_flops / 12.0 * l,
        activation_bytes: 197 * 768 * 4,
        memory_bytes: 1 << 28,
    };
    bench("pipe_edge (fig3 row)", 10, 1000, || {
        black_box(
            strategies::pipe_edge(&fleet, &topo, &[seg(3.0), seg(3.0), seg(6.0)])
                .unwrap()
                .idle_fraction(),
        );
    });

    bench("tensor_parallel 12 layers (fig4/10)", 10, 500, || {
        black_box(
            strategies::tensor_parallel(
                "galaxy",
                &fleet,
                &topo,
                t_flops,
                12,
                197 * 768 * 4 / 3,
                2.0,
                1 << 28,
            )
            .unwrap()
            .total_s,
        );
    });

    bench("ensemble (fig6)", 10, 1000, || {
        black_box(
            strategies::ensemble(
                "devit",
                &fleet,
                &topo,
                &[t_flops / 3.0; 3],
                &[1 << 28; 3],
                4000,
            )
            .unwrap()
            .total_s,
        );
    });

    // full Fig-12 sweep (3 bandwidths × 4 methods)
    bench("fig12_full_sweep", 2, 100, || {
        for mbps in [100.0, 500.0, 1000.0] {
            let topo = Topology::star(3, Link::mbps(mbps), 1);
            black_box(strategies::coformer(&fleet, &topo, &s, 512, 1).unwrap().total_s);
            black_box(
                strategies::tensor_parallel(
                    "g",
                    &fleet,
                    &topo,
                    t_flops,
                    12,
                    197 * 768 * 4 / 3,
                    2.0,
                    1 << 28,
                )
                .unwrap()
                .total_s,
            );
            black_box(
                strategies::pipe_edge(&fleet, &topo, &[seg(3.0), seg(3.0), seg(6.0)])
                    .unwrap()
                    .total_s,
            );
        }
    });

    // cost-model analytics (called inside every policy evaluation)
    let arch = subs()[2].clone();
    bench("flops_per_sample", 100, 10000, || {
        black_box(CostModel::flops_per_sample(&arch));
    });
    bench("memory_bytes", 100, 10000, || {
        black_box(CostModel::memory_bytes(&arch, 16));
    });
}
