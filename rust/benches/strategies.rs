//! Strategy-simulation benches: one per paper table/figure family. These
//! are the generators behind Figs 3/4/10/12 and Tables III/V — each bench
//! measures regenerating one full figure's data points, through the
//! Scenario/registry API the figures themselves use.

use coformer::device::DeviceProfile;
use coformer::metrics::bench::{bench, black_box};
use coformer::model::{Arch, CostModel, Mode, SubModelCfg};
use coformer::net::{Link, Topology};
use coformer::strategies::registry::{CoFormer, Ensemble, PipeEdge, TensorParallel};
use coformer::strategies::{Scenario, Segment, Strategy, Sweep};

fn deit_b() -> Arch {
    let mut a = Arch::uniform(Mode::Patch, 12, 768, 64, 12, 3072, 1000);
    a.img_size = 224;
    a.patch_size = 16;
    a
}

fn subs() -> Vec<Arch> {
    let t = deit_b();
    vec![
        SubModelCfg { layers: 6, dim: 192, heads: 3, mlp_dim: 768 }.to_arch(&t),
        SubModelCfg { layers: 8, dim: 256, heads: 4, mlp_dim: 1024 }.to_arch(&t),
        SubModelCfg { layers: 10, dim: 320, heads: 5, mlp_dim: 1280 }.to_arch(&t),
    ]
}

fn main() {
    println!("== bench: strategies (figure generators) ==");
    let fleet = DeviceProfile::paper_fleet();
    let topo = Topology::star(3, Link::mbps(100.0), 1);
    let t_flops = CostModel::flops_per_sample(&deit_b());
    let sc = Scenario::builder()
        .fleet(fleet)
        .topology(topo)
        .archs(subs())
        .d_i(512)
        .batch(1)
        .build()
        .expect("bench scenario is valid");

    bench("coformer_step (fig9/10/12 rows)", 10, 1000, || {
        black_box(CoFormer.run(&sc).unwrap().total_s());
    });

    let seg = |l: f64| Segment {
        flops: t_flops / 12.0 * l,
        activation_bytes: 197 * 768 * 4,
        memory_bytes: 1 << 28,
    };
    let pipe = PipeEdge::with_segments(vec![seg(3.0), seg(3.0), seg(6.0)]);
    bench("pipe_edge (fig3 row)", 10, 1000, || {
        black_box(pipe.run(&sc).unwrap().idle_fraction());
    });

    let galaxy = TensorParallel {
        label: "galaxy".into(),
        syncs_per_layer: 2.0,
        total_flops: Some(t_flops),
        layers: Some(12),
        shard_bytes: Some(197 * 768 * 4 / 3),
        memory_per_device: Some(1 << 28),
    };
    bench("tensor_parallel 12 layers (fig4/10)", 10, 500, || {
        black_box(galaxy.run(&sc).unwrap().total_s());
    });

    let devit = Ensemble {
        label: "devit".into(),
        member_flops: Some(vec![t_flops / 3.0; 3]),
        member_memory: Some(vec![1 << 28; 3]),
        logit_bytes: Some(4000),
    };
    bench("ensemble (fig6)", 10, 1000, || {
        black_box(devit.run(&sc).unwrap().total_s());
    });

    // full Fig-12 sweep (3 bandwidths × 3 methods) through the sweep runner
    let methods: [&dyn Strategy; 3] = [&CoFormer, &galaxy, &pipe];
    let sweep = Sweep::new(sc.clone()).bandwidths_mbps(&[100.0, 500.0, 1000.0]);
    bench("fig12_full_sweep", 2, 100, || {
        black_box(sweep.run(&methods).unwrap().len());
    });

    // overlap engine with link contention (ISSUE 6): a replicated fleet at
    // 2 Mb/s puts multiple feature payloads on every uplink, so each
    // LinkSchedule reservation walks a busy timeline — the engine's
    // worst-case bookkeeping path, side by side with the serialized run
    let contended = sc
        .to_builder()
        .bandwidth_mbps(2.0)
        .replicas(2)
        .build()
        .expect("contended bench scenario is valid");
    let both_modes = Sweep::new(contended).overlap_modes(&[false, true]);
    bench("overlap_link_contention (paper -- overlap rows)", 5, 200, || {
        black_box(both_modes.run_named(&["coformer_elastic"]).unwrap().len());
    });

    // cost-model analytics (called inside every policy evaluation)
    let arch = subs()[2].clone();
    bench("flops_per_sample", 100, 10000, || {
        black_box(CostModel::flops_per_sample(&arch));
    });
    bench("memory_bytes", 100, 10000, || {
        black_box(CostModel::memory_bytes(&arch, 16));
    });
}
