//! DeBo hot-path benches: GP posterior update + predict, EI candidate
//! scan, full search iterations, and the policy/constraint layer.

use coformer::debo::{expected_improvement, DeBoConfig, DeBoSearch, Gp, Matern32};
use coformer::device::DeviceProfile;
use coformer::evaluator::{AccuracyProxy, LatencyModel, Objective};
use coformer::metrics::bench::{bench, black_box};
use coformer::model::{policy::DeviceCaps, Arch, DecompositionPolicy, Mode, SubModelCfg};
use coformer::net::{Link, Topology};
use coformer::util::Rng;

fn teacher() -> Arch {
    Arch::uniform(Mode::Patch, 4, 96, 24, 4, 192, 20)
}

fn main() {
    println!("== bench: DeBo (GP / EI / search) ==");

    // GP observe+refit at history sizes the search actually reaches
    for n in [16usize, 48, 96] {
        let mut rng = Rng::seed_from_u64(1);
        let pts: Vec<(Vec<f64>, f64)> = (0..n)
            .map(|_| {
                let x: Vec<f64> = (0..12).map(|_| rng.gen_f64()).collect();
                let y = x.iter().sum::<f64>();
                (x, y)
            })
            .collect();
        bench(&format!("gp_refit_n{n}"), 2, 20, || {
            let mut gp = Gp::new(Matern32::default(), 1e-4);
            for (x, y) in &pts {
                gp.observe(x.clone(), *y);
            }
            black_box(gp.len());
        });
    }

    // posterior predict on a fitted GP
    {
        let mut gp = Gp::new(Matern32::default(), 1e-4);
        let mut rng = Rng::seed_from_u64(2);
        for _ in 0..64 {
            let x: Vec<f64> = (0..12).map(|_| rng.gen_f64()).collect();
            let y = x.iter().sum::<f64>();
            gp.observe(x, y);
        }
        let q: Vec<f64> = (0..12).map(|_| rng.gen_f64()).collect();
        bench("gp_predict_n64", 100, 2000, || {
            black_box(gp.predict(&q));
        });
        bench("expected_improvement", 100, 5000, || {
            black_box(expected_improvement(0.7, 0.3, 0.6));
        });
    }

    // objective Ψ evaluation (latency model + accuracy proxy + constraints)
    let devices = DeviceProfile::paper_fleet();
    let topo = Topology::star(3, Link::mbps(100.0), 1);
    let caps = vec![DeviceCaps { max_flops: 1e12, max_memory: 1 << 34 }; 3];
    let t = teacher();
    let obj = Objective {
        latency: LatencyModel {
            devices: &devices,
            topology: &topo,
            predictors: None,
            d_i: 64,
            agg_rows: 4,
        },
        accuracy: AccuracyProxy::default_uncalibrated(),
        teacher: &t,
        caps: &caps,
        delta: 20.0,
        batch: 1,
    };
    let policy = DecompositionPolicy::new(vec![
        SubModelCfg { layers: 2, dim: 24, heads: 1, mlp_dim: 48 },
        SubModelCfg { layers: 3, dim: 32, heads: 1, mlp_dim: 64 },
        SubModelCfg { layers: 3, dim: 40, heads: 2, mlp_dim: 80 },
    ]);
    bench("objective_evaluate", 100, 5000, || {
        black_box(obj.evaluate(&policy));
    });

    // full search at the CLI's default budget (the offline-stage cost)
    bench("debo_search_8init_16iter", 0, 3, || {
        let s = DeBoSearch::new(DeBoConfig {
            init_policies: 8,
            iterations: 16,
            candidates: 128,
            ..Default::default()
        });
        black_box(s.run(&obj, 3).unwrap().best_psi);
    });
}
