//! Coordinator benches: end-to-end collaborative serving throughput under
//! the dynamic batcher, plus the aggregation combiners. Requires
//! `make artifacts`.

use coformer::aggregation;
use coformer::config::SystemConfig;
use coformer::coordinator::{serve_all, RequestPayload, ServeBuilder};
use coformer::data::Dataset;
use coformer::metrics::bench::{bench, black_box};
use coformer::model::Arch;
use coformer::runtime::ExecServer;
use coformer::util::Rng;

fn main() {
    // pure-rust combiners first (no artifacts needed)
    println!("== bench: aggregation combiners ==");
    let mut rng = Rng::seed_from_u64(3);
    let members: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..16 * 20).map(|_| rng.gen_f64() as f32).collect())
        .collect();
    bench("average_16x20x3", 100, 5000, || {
        black_box(aggregation::average(&members, 16, 20).len());
    });
    bench("majority_vote_16x20x3", 100, 5000, || {
        black_box(aggregation::majority_vote(&members, 16, 20).len());
    });

    let artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("bench coordinator: serving part SKIPPED (run `make artifacts`)");
        // gated sections appear in BENCH_*.json as skipped, never silently absent
        coformer::metrics::bench::skip_marker("serving", "artifacts not built");
        return;
    }
    println!("== bench: end-to-end collaborative serving ==");
    let server = ExecServer::start(artifacts.clone()).expect("server");
    let exec = server.handle();
    let m = coformer::runtime::Manifest::load(&artifacts).expect("manifest");
    let dep = m.deployment("edgenet_3dev").unwrap().clone();
    let task = m.task("edgenet").unwrap().clone();
    let ds = Dataset::load(&artifacts, &task.splits["test"]).expect("ds");
    let archs: Vec<Arch> = dep
        .members
        .iter()
        .map(|n| m.model(n).unwrap().arch.clone())
        .collect();
    for member in &dep.members {
        exec.warmup(member).unwrap();
    }
    let coord =
        ServeBuilder::new(SystemConfig::paper_default(), exec, dep, archs, ds.x_stride())
            .start()
            .expect("coordinator");
    let handle = coord.handle();

    // single blocking request (unbatched path)
    let one = RequestPayload::F32(ds.gather_x_f32(&[0]));
    bench("serve_single_request", 5, 100, || {
        black_box(handle.infer(one.clone()).unwrap().prediction);
    });

    // pipelined burst of 64 (batcher coalesces)
    bench("serve_burst_64", 2, 20, || {
        let payloads: Vec<RequestPayload> =
            (0..64).map(|i| RequestPayload::F32(ds.gather_x_f32(&[i]))).collect();
        black_box(serve_all(&handle, payloads).unwrap().len());
    });

    let stats = coord.shutdown().expect("stats");
    println!(
        "serving stats: {} requests in {} batches (mean batch {:.1}), host wall p50 {:.2} ms",
        stats.requests,
        stats.batches,
        stats.requests as f64 / stats.batches.max(1) as f64,
        stats.wall_latency.p50_ms()
    );
}
