//! The in-repo invariant linter behind `cargo xtask lint`.
//!
//! Five rules (see the README's "Static analysis & model checking"):
//!
//! - `no-panic-in-lib` — no `.unwrap()` / `.expect(...)` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` in non-test library code;
//!   binaries (`main.rs`, `bin/`) are exempt.
//! - `determinism` — no wall-clock (`SystemTime::now`, `Instant::now`) or
//!   OS-randomness tokens anywhere, and no `HashMap`/`HashSet` in
//!   `strategies/` or `metrics/`, whose iteration order can leak into
//!   reports.
//! - `config-gate` — every `pub struct *Policy` in `config/mod.rs` must be
//!   reachable from `SystemConfig::validate`.
//! - `atomics-ordering` — atomics use `Ordering::SeqCst` unless a pragma
//!   justifies otherwise, and `coordinator/` goes through
//!   `crate::util::sync` so loom can swap the types under `cfg(loom)`.
//! - `units` (ISSUE 9) — unit-conversion literals (`* 1e3`, `/ 1e6`,
//!   `* 8.0`, …) are confined to `util/units.rs`, and any `f64` binding
//!   naming a physical quantity (latency, bandwidth, energy, …) must carry
//!   a unit suffix (`_ms`, `_mbps`, `_j`, …) or a pragma. Binaries are NOT
//!   exempt — their report tables quote the same quantities.
//!
//! Intentional violations carry `// lint:allow(<rule>): <reason>` on (or
//! directly above) the offending line. Malformed and unused pragmas are
//! themselves violations, reported under the synthetic rule `pragma`.

pub mod rules;
pub mod scan;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// One line-anchored lint finding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the lint root, `/`-separated (rules scope by dir).
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Diagnostic {
    /// One machine-readable JSON object (the `--json` line format the CI
    /// static-analysis job archives as an artifact).
    pub fn to_json(&self) -> String {
        format!(
            r#"{{"file":"{}","line":{},"rule":"{}","message":"{}"}}"#,
            json_escape(&self.file),
            self.line,
            json_escape(self.rule),
            json_escape(&self.message)
        )
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// messages quote source tokens, so `"` and `\` do occur.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Lint one source file. `rel` is the path relative to the lint root with
/// `/` separators — several rules are directory-scoped.
pub fn lint_source(rel: &str, text: &str) -> Vec<Diagnostic> {
    let s = scan::scan(text);
    let mut diags = rules::line_rules(rel, &s.lines);
    if rel == "config/mod.rs" {
        diags.extend(rules::config_gate(rel, &s.lines));
    }
    let mut used = vec![false; s.pragmas.len()];
    let mut out = Vec::new();
    for d in diags {
        match s.pragmas.iter().position(|p| p.rule == d.rule && p.target == d.line) {
            Some(pi) => used[pi] = true,
            None => out.push(d),
        }
    }
    for (ln, msg) in s.malformed {
        out.push(Diagnostic { file: rel.to_string(), line: ln, rule: "pragma", message: msg });
    }
    for (pi, p) in s.pragmas.iter().enumerate() {
        if !used[pi] {
            out.push(Diagnostic {
                file: rel.to_string(),
                line: p.line,
                rule: "pragma",
                message: format!(
                    "unused lint:allow({}) — nothing to suppress on line {}",
                    p.rule, p.target
                ),
            });
        }
    }
    out
}

/// Walk `root`, lint every `.rs` file, print diagnostics — one
/// `<root>/<file>:<line>: [<rule>] <message>` line each, or one JSON
/// object per line under `json` — and exit nonzero on any. The JSON mode
/// keeps the violation count on stderr so stdout stays pure JSONL.
pub fn run(root: &Path, json: bool) -> ExitCode {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files);
    files.sort();
    let mut diags = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        match std::fs::read_to_string(path) {
            Ok(text) => diags.extend(lint_source(&rel, &text)),
            Err(e) => {
                eprintln!("xtask lint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        }
    }
    diags.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
    for d in &diags {
        if json {
            println!("{}", d.to_json());
        } else {
            println!("{}/{}:{}: [{}] {}", root.display(), d.file, d.line, d.rule, d.message);
        }
    }
    if json {
        eprintln!("{} violation(s)", diags.len());
    } else {
        println!("{} violation(s)", diags.len());
    }
    if diags.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> String {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("fixture {} unreadable: {e}", path.display()))
    }

    fn rules_of(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn no_panic_flags_unwrap_and_expect_with_lines() {
        let diags = lint_source("util/fx.rs", &fixture("no_panic_violating.rs"));
        assert_eq!(rules_of(&diags), ["no-panic-in-lib", "no-panic-in-lib"]);
        assert!(diags[0].message.contains("`.unwrap()`"), "{diags:?}");
        assert!(diags[1].message.contains("`.expect`"), "{diags:?}");
        assert!(diags[0].line < diags[1].line);
    }

    #[test]
    fn no_panic_exempts_binaries() {
        let text = fixture("no_panic_violating.rs");
        assert!(lint_source("main.rs", &text).is_empty());
        assert!(lint_source("bin/paper.rs", &text).is_empty());
    }

    #[test]
    fn no_panic_clean_file_passes_and_tests_are_exempt() {
        assert!(lint_source("util/fx.rs", &fixture("no_panic_clean.rs")).is_empty());
    }

    #[test]
    fn no_panic_pragma_suppresses_and_counts_as_used() {
        assert!(lint_source("util/fx.rs", &fixture("no_panic_pragma.rs")).is_empty());
    }

    #[test]
    fn determinism_flags_wall_clock() {
        let diags = lint_source("util/fx.rs", &fixture("determinism_violating.rs"));
        assert_eq!(rules_of(&diags), ["determinism", "determinism"]);
        assert!(diags[0].message.contains("Instant::now"), "{diags:?}");
        assert!(diags[1].message.contains("SystemTime::now"), "{diags:?}");
    }

    #[test]
    fn determinism_pragma_suppresses() {
        assert!(lint_source("util/fx.rs", &fixture("determinism_pragma.rs")).is_empty());
    }

    #[test]
    fn hash_maps_banned_only_in_ordered_output_dirs() {
        let text = fixture("maps_violating.rs");
        let diags = lint_source("strategies/fx.rs", &text);
        assert!(!diags.is_empty());
        assert!(diags.iter().all(|d| d.rule == "determinism"), "{diags:?}");
        assert!(diags[0].message.contains("strategies/"), "{diags:?}");
        let diags = lint_source("metrics/fx.rs", &text);
        assert!(diags.iter().any(|d| d.message.contains("metrics/")), "{diags:?}");
        // outside the scoped dirs a HashMap is fine
        assert!(lint_source("runtime/fx.rs", &text).is_empty());
        assert!(lint_source("strategies/fx.rs", &fixture("maps_clean.rs")).is_empty());
    }

    #[test]
    fn non_seqcst_orderings_flagged_everywhere() {
        let diags = lint_source("runtime/fx.rs", &fixture("atomics_violating.rs"));
        assert_eq!(rules_of(&diags), ["atomics-ordering"]);
        assert!(diags[0].message.contains("Ordering::Relaxed"), "{diags:?}");
    }

    #[test]
    fn coordinator_must_use_the_sync_shim() {
        let diags = lint_source("coordinator/fx.rs", &fixture("atomics_violating.rs"));
        assert_eq!(rules_of(&diags), ["atomics-ordering", "atomics-ordering"]);
        assert!(diags[0].message.contains("util::sync"), "{diags:?}");
    }

    #[test]
    fn seqcst_and_pragmad_atomics_pass() {
        assert!(lint_source("runtime/fx.rs", &fixture("atomics_clean.rs")).is_empty());
    }

    #[test]
    fn config_gate_reports_unvalidated_policy() {
        let diags = lint_source("config/mod.rs", &fixture("config_gate_violating.rs"));
        assert_eq!(rules_of(&diags), ["config-gate"]);
        assert!(diags[0].message.contains("OrphanPolicy"), "{diags:?}");
        // the rule is scoped to config/mod.rs
        assert!(lint_source("config/other.rs", &fixture("config_gate_violating.rs")).is_empty());
    }

    #[test]
    fn config_gate_accepts_transitively_validated_policies() {
        assert!(lint_source("config/mod.rs", &fixture("config_gate_clean.rs")).is_empty());
    }

    #[test]
    fn units_flags_bare_quantities_and_conversion_literals_with_lines() {
        let diags = lint_source("util/fx.rs", &fixture("units_violating.rs"));
        assert_eq!(rules_of(&diags), ["units", "units", "units", "units"]);
        assert!(diags[0].message.contains("`deadline`"), "{diags:?}");
        assert!(diags[1].message.contains("`latency`"), "{diags:?}");
        assert!(diags[2].message.contains("`* 1e3`"), "{diags:?}");
        assert!(diags[3].message.contains("`* 8.0`"), "{diags:?}");
        assert!(diags[0].line < diags[2].line && diags[2].line < diags[3].line);
    }

    #[test]
    fn units_applies_to_binaries_too() {
        // unlike no-panic-in-lib: the binaries' report tables quote the
        // same physical quantities the library computes
        let text = fixture("units_violating.rs");
        assert_eq!(lint_source("main.rs", &text).len(), 4);
        assert_eq!(lint_source("bin/paper.rs", &text).len(), 4);
    }

    #[test]
    fn units_conversion_constants_allowed_only_in_units_rs() {
        let text = "pub fn f(x: f64) -> f64 {\n    x * 1e3\n}\n";
        assert!(lint_source("util/units.rs", text).is_empty());
        assert_eq!(rules_of(&lint_source("util/other.rs", text)), ["units"]);
        assert_eq!(rules_of(&lint_source("net/mod.rs", text)), ["units"]);
    }

    #[test]
    fn units_clean_and_pragmad_fixtures_pass() {
        assert!(lint_source("util/fx.rs", &fixture("units_clean.rs")).is_empty());
        assert!(lint_source("util/fx.rs", &fixture("units_pragma.rs")).is_empty());
    }

    #[test]
    fn units_literal_matcher_respects_number_boundaries() {
        // `* 1e30` contains the `* 1e3` byte pattern but is a magnitude,
        // not a conversion — the matcher must not fire inside it
        let text = "pub fn f(x: f64) -> f64 {\n    x * 1e30\n}\n";
        assert!(lint_source("util/fx.rs", text).is_empty());
        // `* 8.05` must not trip the `* 8.0` pattern either
        let text = "pub fn g(x: f64) -> f64 {\n    x * 8.05\n}\n";
        assert!(lint_source("util/fx.rs", text).is_empty());
    }

    #[test]
    fn units_suffix_rule_ignores_paths_types_and_dimensionless_names() {
        // `::` path separators, generic bounds, non-f64 types and names
        // with no quantity keyword never trip the suffix rule
        let text = concat!(
            "pub fn f<T: Copy>(v: std::vec::Vec<u64>, fill: f64) -> f64 {\n",
            "    let deadline_ms: f64 = fill;\n",
            "    deadline_ms\n",
            "}\n",
        );
        assert!(lint_source("util/fx.rs", text).is_empty());
        // a `let` binding with a bare quantity name IS flagged
        let text = "pub fn g() {\n    let deadline: f64 = 0.0;\n    let _ = deadline;\n}\n";
        assert_eq!(rules_of(&lint_source("util/fx.rs", text)), ["units"]);
    }

    #[test]
    fn diagnostics_render_as_one_json_object_each() {
        let d = Diagnostic {
            file: "net/mod.rs".to_string(),
            line: 9,
            rule: "units",
            message: "bad `\"x\\y\"`".to_string(),
        };
        assert_eq!(
            d.to_json(),
            r#"{"file":"net/mod.rs","line":9,"rule":"units","message":"bad `\"x\\y\"`"}"#
        );
        assert_eq!(json_escape("a\nb\tc"), "a\\nb\\tc");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn malformed_pragmas_are_violations() {
        let diags = lint_source("util/fx.rs", &fixture("pragma_malformed.rs"));
        assert_eq!(rules_of(&diags), ["pragma", "pragma"]);
        assert!(diags[0].message.contains("unknown lint rule"), "{diags:?}");
        assert!(diags[1].message.contains("must carry a reason"), "{diags:?}");
    }

    #[test]
    fn unused_pragmas_are_violations() {
        let diags = lint_source("util/fx.rs", &fixture("pragma_unused.rs"));
        assert_eq!(rules_of(&diags), ["pragma"]);
        assert!(diags[0].message.contains("unused lint:allow"), "{diags:?}");
    }

    #[test]
    fn tokens_in_strings_and_comments_do_not_trip() {
        let text = concat!(
            "pub fn doc() -> &'static str {\n",
            "    // Instant::now() would break things\n",
            "    \"call .unwrap() and Instant::now\"\n",
            "}\n",
        );
        assert!(lint_source("util/fx.rs", text).is_empty());
        let raw = concat!(
            "pub fn raw() -> &'static str {\n",
            "    r#\"panic!(\"nope\") .expect(\"#\n",
            "}\n",
        );
        assert!(lint_source("util/fx.rs", raw).is_empty());
    }

    #[test]
    fn cfg_test_modules_are_exempt() {
        let text = concat!(
            "pub fn ok() {}\n\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() {\n",
            "        Some(1).unwrap();\n",
            "    }\n",
            "}\n",
        );
        assert!(lint_source("util/fx.rs", text).is_empty());
    }

    #[test]
    fn real_source_tree_is_lint_clean() {
        // the acceptance bar: HEAD lints clean; run against rust/src when
        // present (always, in-repo) so regressions fail tier-1 too
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(|p| p.join("src"))
            .filter(|p| p.is_dir());
        let Some(root) = root else { return };
        let mut files = Vec::new();
        collect_rs_files(&root, &mut files);
        assert!(!files.is_empty(), "no sources under {}", root.display());
        let mut all = Vec::new();
        for path in &files {
            let rel = path
                .strip_prefix(&root)
                .unwrap_or(path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            let text = std::fs::read_to_string(path).expect("source readable");
            all.extend(lint_source(&rel, &text));
        }
        assert!(all.is_empty(), "lint violations on HEAD: {all:#?}");
    }
}
