//! The rule set: per-line token rules and the `config-gate` reachability
//! rule. Every rule matches against stripped code (see [`super::scan`]), so
//! strings, comments and test regions are already out of the picture.

use std::collections::{BTreeMap, BTreeSet};

use super::scan::Line;
use super::Diagnostic;

/// `no-panic-in-lib`: panicking constructs banned from library code
/// (binaries — `main.rs` and `bin/` — are exempt).
const NO_PANIC: [&str; 7] = [
    ".unwrap()",
    ".unwrap_err()",
    ".expect(",
    "panic!(",
    "unreachable!(",
    "todo!(",
    "unimplemented!(",
];

/// `determinism`: wall-clock and OS-randomness tokens banned everywhere
/// (pragma intentional telemetry sites).
const DETERMINISM: [&str; 7] = [
    "SystemTime::now",
    "Instant::now",
    "thread_rng",
    "from_entropy",
    "rand::random",
    "getrandom",
    "RandomState",
];

/// Directories whose output paths must not iterate hash maps.
const ORDERED_MAP_DIRS: [&str; 2] = ["strategies/", "metrics/"];

/// `atomics-ordering`: every non-SeqCst ordering needs a pragma.
const NON_SEQCST: [&str; 4] =
    ["Ordering::Relaxed", "Ordering::Acquire", "Ordering::Release", "Ordering::AcqRel"];

/// `units` (ISSUE 9): unit-conversion literals, banned everywhere except
/// `util/units.rs` — a conversion must name both units
/// (`Secs::to_millis`, `Bytes::to_bits`), never reach for a scale factor.
/// Matched on rustfmt-normalized spacing (`x * 1e3`), with a trailing
/// number-boundary check so `* 1e30` never trips the `* 1e3` pattern.
const CONVERSION_LITERALS: [&str; 13] = [
    "* 1e3",
    "/ 1e3",
    "* 1e6",
    "/ 1e6",
    "* 1e9",
    "/ 1e9",
    "* 8.0",
    "/ 8.0",
    "* 1000.0",
    "/ 1000.0",
    "* 1e-3",
    "* 1e-6",
    "* 1e-9",
];

/// `units`: an `f64` binding whose name contains one of these words is
/// carrying a physical quantity and must say which unit.
const QUANTITY_KEYWORDS: [&str; 8] =
    ["latency", "bandwidth", "deadline", "energy", "power", "duration", "elapsed", "timeout"];

/// Accepted unit suffixes (the binding's last `_`-segment) — physical
/// units plus the dimensionless markers a quantity-adjacent multiplier
/// legitimately carries (`deadline_factor`, `degraded_slack`).
const UNIT_SUFFIXES: [&str; 30] = [
    "s", "ms", "us", "ns", "secs", "millis", "micros", "nanos", "bps", "mbps", "gbps", "bits",
    "bytes", "kb", "mb", "gb", "flops", "mflops", "gflops", "j", "mj", "joules", "w", "mw",
    "watts", "hz", "rps", "frac", "factor", "slack",
];

fn identish(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn is_token(code: &str, pat: &str, pos: usize) -> bool {
    let before = code[..pos].chars().next_back();
    let after = code[pos + pat.len()..].chars().next();
    !before.is_some_and(identish) && !after.is_some_and(identish)
}

/// Find `pat` in `code`; identifier-leading patterns are matched on word
/// boundaries so e.g. `Instant::now` never matches inside a longer ident.
fn find_token(code: &str, pat: &str) -> Option<usize> {
    let first = pat.chars().next()?;
    let mut start = 0usize;
    while let Some(off) = code[start..].find(pat) {
        let pos = start + off;
        if !(first.is_alphanumeric() || first == '_') || is_token(code, pat, pos) {
            return Some(pos);
        }
        start = pos + pat.len();
    }
    None
}

/// Find a conversion-literal pattern, rejecting matches that continue into
/// a longer number or identifier (`* 1e3` must not match inside `* 1e30`).
/// [`find_token`] can't do this: its boundary checks only engage for
/// identifier-leading patterns, and these start with `*` / `/`.
fn find_conversion_literal(code: &str, pat: &str) -> Option<usize> {
    let mut start = 0usize;
    while let Some(off) = code[start..].find(pat) {
        let pos = start + off;
        let after = code[pos + pat.len()..].chars().next();
        if !after.is_some_and(identish) {
            return Some(pos);
        }
        start = pos + pat.len();
    }
    None
}

/// Normalized base of a declared type: references, lifetimes and `mut`
/// stripped, so `&'a [f64]` and `&mut Vec<f64>` both resolve.
fn is_f64_quantity_type(ty: &str) -> bool {
    let mut t = ty.trim();
    loop {
        if let Some(rest) = t.strip_prefix('&') {
            t = rest.trim_start();
            continue;
        }
        if t.starts_with('\'') {
            let skip: usize = t.chars().take_while(|&c| c == '\'' || identish(c)).map(char::len_utf8).sum();
            t = t[skip..].trim_start();
            continue;
        }
        if let Some(rest) = t.strip_prefix("mut ") {
            t = rest.trim_start();
            continue;
        }
        break;
    }
    matches!(t, "f64" | "[f64]" | "Vec<f64>" | "VecDeque<f64>" | "Option<f64>")
}

/// Find an `ident: f64`-shaped field/param whose name says it carries a
/// physical quantity ([`QUANTITY_KEYWORDS`]) without saying in which unit
/// ([`UNIT_SUFFIXES`]). Returns the offending identifier.
fn unsuffixed_quantity(code: &str) -> Option<String> {
    for (pos, _) in code.match_indices(':') {
        // path separators (`std::f64`) are not declarations
        if code[..pos].ends_with(':') || code[pos + 1..].starts_with(':') {
            continue;
        }
        let before = code[..pos].trim_end();
        let name_len: usize =
            before.chars().rev().take_while(|&c| identish(c)).map(char::len_utf8).sum();
        let name = &before[before.len() - name_len..];
        // fields and params are snake_case; a leading capital is a generic
        // bound (`T: Copy`) or enum path, not a binding
        match name.chars().next() {
            Some(c) if c.is_lowercase() || c == '_' => {}
            _ => continue,
        }
        let after = &code[pos + 1..];
        let end = after
            .find(|c: char| matches!(c, ',' | ')' | '{' | '}' | ';' | '='))
            .unwrap_or(after.len());
        if !is_f64_quantity_type(&after[..end]) {
            continue;
        }
        if !QUANTITY_KEYWORDS.iter().any(|k| name.contains(k)) {
            continue;
        }
        let last_segment = name.rsplit('_').next().unwrap_or(name);
        if UNIT_SUFFIXES.contains(&last_segment) {
            continue;
        }
        return Some(name.to_string());
    }
    None
}

/// All per-line token rules over one file.
pub fn line_rules(rel: &str, lines: &[Line]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let is_binary = rel == "main.rs" || rel.starts_with("bin/");
    let in_map_scope = ORDERED_MAP_DIRS.iter().any(|d| rel.starts_with(d));
    let in_coordinator = rel.starts_with("coordinator/");
    let is_units_home = rel == "util/units.rs";
    let top_dir = rel.split('/').next().unwrap_or(rel);
    for (idx, l) in lines.iter().enumerate() {
        if l.in_test || l.code.trim().is_empty() {
            continue;
        }
        let code = &l.code;
        let line = idx + 1;
        if !is_binary {
            for pat in NO_PANIC {
                if find_token(code, pat).is_some() {
                    diags.push(Diagnostic {
                        file: rel.to_string(),
                        line,
                        rule: "no-panic-in-lib",
                        message: format!(
                            "`{}` in library code — return a typed error instead",
                            pat.trim_end_matches('(')
                        ),
                    });
                }
            }
        }
        for pat in DETERMINISM {
            if find_token(code, pat).is_some() {
                diags.push(Diagnostic {
                    file: rel.to_string(),
                    line,
                    rule: "determinism",
                    message: format!("`{pat}` breaks run-to-run determinism"),
                });
            }
        }
        if in_map_scope {
            for pat in ["HashMap", "HashSet"] {
                if find_token(code, pat).is_some() {
                    diags.push(Diagnostic {
                        file: rel.to_string(),
                        line,
                        rule: "determinism",
                        message: format!(
                            "`{pat}` in {top_dir}/ — iteration order can leak into \
                             output; use BTreeMap/BTreeSet or sort explicitly"
                        ),
                    });
                }
            }
        }
        for pat in NON_SEQCST {
            if find_token(code, pat).is_some() {
                diags.push(Diagnostic {
                    file: rel.to_string(),
                    line,
                    rule: "atomics-ordering",
                    message: format!(
                        "`{pat}` — admission-plane atomics must use Ordering::SeqCst \
                         (or carry a pragma)"
                    ),
                });
            }
        }
        if in_coordinator && find_token(code, "std::sync::atomic").is_some() {
            diags.push(Diagnostic {
                file: rel.to_string(),
                line,
                rule: "atomics-ordering",
                message: "direct std::sync::atomic use in coordinator/ — go through \
                          crate::util::sync so loom can swap it"
                    .to_string(),
            });
        }
        if !is_units_home {
            for pat in CONVERSION_LITERALS {
                if find_conversion_literal(code, pat).is_some() {
                    diags.push(Diagnostic {
                        file: rel.to_string(),
                        line,
                        rule: "units",
                        message: format!(
                            "unit-conversion literal `{pat}` outside util/units.rs — \
                             convert by naming both units (e.g. Secs::to_millis, \
                             Bytes::to_bits)"
                        ),
                    });
                }
            }
        }
        if let Some(name) = unsuffixed_quantity(code) {
            diags.push(Diagnostic {
                file: rel.to_string(),
                line,
                rule: "units",
                message: format!(
                    "`{name}` is a raw f64 physical quantity with no unit suffix \
                     (_ms, _s, _mbps, _gflops, _mb, _j, …) — suffix it, carry a \
                     util::units newtype, or add a lint:allow(units) pragma"
                ),
            });
        }
    }
    diags
}

fn is_identifier(s: &str) -> bool {
    let mut cs = s.chars();
    match cs.next() {
        Some(c) if c.is_alphabetic() || c == '_' => {}
        _ => return false,
    }
    cs.all(identish)
}

fn struct_name(stripped: &str) -> String {
    let after = stripped.split_once("struct ").map_or("", |x| x.1);
    after.split('{').next().unwrap_or("").split('(').next().unwrap_or("").trim().to_string()
}

fn base_type(ftype: &str) -> String {
    let head = ftype.split('<').next().unwrap_or("");
    head.rsplit("::").next().unwrap_or("").trim().to_string()
}

/// `config-gate`: every `pub struct *Policy` in `config/mod.rs` must be
/// reachable from `SystemConfig::validate` through `self.<field>.validate()`
/// edges — otherwise a policy can be constructed that no validation path
/// ever checks.
pub fn config_gate(rel: &str, lines: &[Line]) -> Vec<Diagnostic> {
    // struct name -> {field -> base type}; struct name -> definition line
    let mut fields: BTreeMap<String, BTreeMap<String, String>> = BTreeMap::new();
    let mut def_line: BTreeMap<String, usize> = BTreeMap::new();
    let mut policies: Vec<String> = Vec::new();
    let mut i = 0usize;
    while i < lines.len() {
        if lines[i].in_test {
            i += 1;
            continue;
        }
        let stripped = lines[i].code.trim().to_string();
        if stripped.starts_with("pub struct ") || stripped.starts_with("struct ") {
            let name = struct_name(&stripped);
            def_line.insert(name.clone(), i + 1);
            if stripped.starts_with("pub struct ") && name.ends_with("Policy") {
                policies.push(name.clone());
            }
            let mut fmap: BTreeMap<String, String> = BTreeMap::new();
            let mut j = i;
            let mut depth: i64 = 0;
            let mut opened = false;
            while j < lines.len() {
                let c2 = &lines[j].code;
                for ch in c2.chars() {
                    if ch == '{' {
                        depth += 1;
                        opened = true;
                    } else if ch == '}' {
                        depth -= 1;
                    }
                }
                if (opened && j > i) || (opened && c2.contains('{')) {
                    let s2 = c2.trim();
                    if s2.contains(':') {
                        let fname = s2.split(':').next().unwrap_or("").replace("pub ", "");
                        let fname = fname.trim();
                        let ftype = s2.split_once(':').map_or("", |x| x.1);
                        let ftype = ftype.trim().trim_end_matches(',');
                        if is_identifier(fname) {
                            fmap.insert(fname.to_string(), base_type(ftype));
                        }
                    }
                }
                if opened && depth == 0 {
                    break;
                }
                j += 1;
            }
            fields.insert(name, fmap);
            i = j + 1;
            continue;
        }
        i += 1;
    }

    // inherent impl blocks -> `fn validate` bodies -> field-type edges
    let mut edges: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    let mut i = 0usize;
    while i < lines.len() {
        let stripped = lines[i].code.trim().to_string();
        let inherent = stripped
            .strip_prefix("impl ")
            .filter(|_| !stripped.contains(" for "))
            .map(|rest| rest.split('{').next().unwrap_or("").trim().to_string());
        if let Some(name) = inherent {
            let mut depth: i64 = 0;
            let mut opened = false;
            let mut j = i;
            let mut end = lines.len().saturating_sub(1);
            while j < lines.len() {
                for ch in lines[j].code.chars() {
                    if ch == '{' {
                        depth += 1;
                        opened = true;
                    } else if ch == '}' {
                        depth -= 1;
                    }
                }
                if opened && depth == 0 {
                    end = j;
                    break;
                }
                j += 1;
            }
            let mut k = i;
            while k <= end {
                let has_validate = lines[k].code.trim().contains("fn validate");
                if has_validate && !lines[k].in_test {
                    let mut fd: i64 = 0;
                    let mut fopened = false;
                    let mut m = k;
                    while m <= end {
                        let c3 = &lines[m].code;
                        let mut pos = 0usize;
                        while let Some(off) = c3[pos..].find("self.") {
                            let p = pos + off;
                            let restc = &c3[p + 5..];
                            let flen: usize = restc
                                .chars()
                                .take_while(|c| identish(*c))
                                .map(char::len_utf8)
                                .sum();
                            let fname = &restc[..flen];
                            if restc[flen..].starts_with(".validate") {
                                if let Some(base) = fields.get(&name).and_then(|f| f.get(fname)) {
                                    edges.entry(name.clone()).or_default().insert(base.clone());
                                }
                            }
                            pos = p + 5;
                        }
                        for ch in c3.chars() {
                            if ch == '{' {
                                fd += 1;
                                fopened = true;
                            } else if ch == '}' {
                                fd -= 1;
                            }
                        }
                        if fopened && fd == 0 {
                            break;
                        }
                        m += 1;
                    }
                    k = m;
                }
                k += 1;
            }
            i = end + 1;
            continue;
        }
        i += 1;
    }

    let mut reached: BTreeSet<String> = BTreeSet::new();
    let mut stack = vec!["SystemConfig".to_string()];
    while let Some(cur) = stack.pop() {
        if !reached.insert(cur.clone()) {
            continue;
        }
        if let Some(nexts) = edges.get(&cur) {
            for nxt in nexts {
                stack.push(nxt.clone());
            }
        }
    }

    let mut diags = Vec::new();
    for p in &policies {
        if !reached.contains(p) {
            diags.push(Diagnostic {
                file: rel.to_string(),
                line: def_line.get(p).copied().unwrap_or(1),
                rule: "config-gate",
                message: format!(
                    "pub policy struct `{p}` is not validated from SystemConfig::validate"
                ),
            });
        }
    }
    diags
}
