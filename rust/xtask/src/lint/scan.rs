//! Lexical pass: strip comments and string/char literals, mark
//! `#[cfg(test)]` / `#[test]` regions, and parse
//! `// lint:allow(<rule>): <reason>` pragmas.
//!
//! The stripped per-line code is what the rules in [`super::rules`] match
//! against, so a banned token inside a string, a comment or test-only code
//! never trips a rule.

/// The rule names a pragma may name.
pub const RULES: [&str; 5] =
    ["no-panic-in-lib", "determinism", "config-gate", "atomics-ordering", "units"];

/// One source line after stripping: code with comments and literal bodies
/// removed, the comment text (for pragma parsing), and whether the line
/// sits inside a `#[cfg(test)]` / `#[test]` item.
#[derive(Clone, Debug, Default)]
pub struct Line {
    pub code: String,
    pub comment: String,
    pub in_test: bool,
}

/// A parsed `// lint:allow(rule): reason` pragma. `target` is the 1-based
/// line the suppression applies to: the pragma's own line when it carries
/// code, otherwise the next line that does.
#[derive(Clone, Debug)]
pub struct Pragma {
    pub rule: String,
    pub target: usize,
    pub line: usize,
}

/// Scanner output: stripped lines, valid pragmas, and malformed-pragma
/// notes as `(1-based line, message)`.
#[derive(Debug, Default)]
pub struct Scan {
    pub lines: Vec<Line>,
    pub pragmas: Vec<Pragma>,
    pub malformed: Vec<(usize, String)>,
}

enum State {
    Code,
    LineComment,
    Block,
    Str,
    RawStr,
}

pub fn scan(text: &str) -> Scan {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut i = 0usize;
    let mut code_lines: Vec<String> = Vec::new();
    let mut comment_lines: Vec<String> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Code;
    let mut depth = 0usize; // block-comment nesting
    let mut raw_hashes = 0usize;

    while i < n {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            code_lines.push(std::mem::take(&mut code));
            comment_lines.push(std::mem::take(&mut comment));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let nxt = chars.get(i + 1).copied().unwrap_or('\0');
                if c == '/' && nxt == '/' {
                    state = State::LineComment;
                    i += 2;
                    continue;
                }
                if c == '/' && nxt == '*' {
                    state = State::Block;
                    depth = 1;
                    i += 2;
                    continue;
                }
                // raw strings: r"..." / r#"..."# / br#"..."#
                if c == 'r' || c == 'b' {
                    let mut j = i;
                    if c == 'b' && chars.get(j + 1) == Some(&'r') {
                        j += 1;
                    }
                    if chars[j] == 'r' {
                        let mut k = j + 1;
                        let mut h = 0usize;
                        while k < n && chars[k] == '#' {
                            h += 1;
                            k += 1;
                        }
                        if k < n && chars[k] == '"' {
                            code.push('"');
                            raw_hashes = h;
                            state = State::RawStr;
                            i = k + 1;
                            continue;
                        }
                    }
                }
                if c == '"' {
                    code.push('"');
                    state = State::Str;
                    i += 1;
                    continue;
                }
                if c == '\'' {
                    // char literal vs lifetime: a literal iff the quote is
                    // followed by an escape, or closes two chars later
                    let n1 = chars.get(i + 1).copied().unwrap_or('\0');
                    let n2 = chars.get(i + 2).copied().unwrap_or('\0');
                    if n1 == '\\' || (n1 != '\'' && n2 == '\'') {
                        code.push_str("''");
                        i += 1;
                        if chars.get(i) == Some(&'\\') {
                            i += 1; // escape head
                            while i < n && chars[i] != '\'' {
                                i += 1; // escape body
                            }
                        } else {
                            i += 1; // the char itself
                        }
                        i += 1; // closing quote
                        continue;
                    }
                    code.push('\'');
                    i += 1;
                    continue;
                }
                code.push(c);
                i += 1;
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::Block => {
                let nxt = chars.get(i + 1).copied().unwrap_or('\0');
                if c == '/' && nxt == '*' {
                    depth += 1;
                    i += 2;
                    comment.push_str("/*");
                    continue;
                }
                if c == '*' && nxt == '/' {
                    depth -= 1;
                    i += 2;
                    if depth == 0 {
                        state = State::Code;
                    } else {
                        comment.push_str("*/");
                    }
                    continue;
                }
                comment.push(c);
                i += 1;
            }
            State::Str => {
                if c == '\\' {
                    i += 2;
                    continue;
                }
                if c == '"' {
                    code.push('"');
                    state = State::Code;
                }
                i += 1;
            }
            State::RawStr => {
                if c == '"' {
                    let mut k = i + 1;
                    let mut h = 0usize;
                    while k < n && h < raw_hashes && chars[k] == '#' {
                        h += 1;
                        k += 1;
                    }
                    if h == raw_hashes {
                        code.push('"');
                        state = State::Code;
                        i = k;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
    code_lines.push(code);
    comment_lines.push(comment);

    let mut lines: Vec<Line> = code_lines
        .into_iter()
        .zip(comment_lines)
        .map(|(code, comment)| Line { code, comment, in_test: false })
        .collect();

    // test regions: the item following a test attribute is exempt
    let mut ln = 0usize;
    while ln < lines.len() {
        let t = lines[ln].code.trim();
        if t.starts_with("#[cfg(test") || t.starts_with("#[test]") {
            mark_region(&mut lines, ln);
        }
        ln += 1;
    }

    let (pragmas, malformed) = parse_pragmas(&lines);
    Scan { lines, pragmas, malformed }
}

/// Mark the item following an attribute at `start` as test code: brace-match
/// to the item's closing `}`, or to a `;` at depth 0 before any brace opens.
fn mark_region(lines: &mut [Line], start: usize) {
    let mut depth: i64 = 0;
    let mut opened = false;
    let mut j = start;
    while j < lines.len() {
        let code = lines[j].code.clone();
        for ch in code.chars() {
            if !opened && ch == ';' && depth == 0 {
                for l in &mut lines[start..=j] {
                    l.in_test = true;
                }
                return;
            }
            if ch == '{' {
                depth += 1;
                opened = true;
            } else if ch == '}' {
                depth -= 1;
                if opened && depth == 0 {
                    for l in &mut lines[start..=j] {
                        l.in_test = true;
                    }
                    return;
                }
            }
        }
        j += 1;
    }
    for l in &mut lines[start..] {
        l.in_test = true;
    }
}

fn parse_pragmas(lines: &[Line]) -> (Vec<Pragma>, Vec<(usize, String)>) {
    let mut pragmas = Vec::new();
    let mut malformed = Vec::new();
    for (idx, l) in lines.iter().enumerate() {
        let com = &l.comment;
        let Some(pos) = com.find("lint:allow(") else { continue };
        let rest = &com[pos + "lint:allow(".len()..];
        let Some(close) = rest.find(')') else {
            malformed.push((idx + 1, "malformed lint:allow pragma: missing ')'".to_string()));
            continue;
        };
        let rule = rest[..close].trim().to_string();
        let after = &rest[close + 1..];
        if !RULES.contains(&rule.as_str()) && rule != "pragma" {
            malformed.push((idx + 1, format!("unknown lint rule '{rule}' in lint:allow")));
            continue;
        }
        let Some(reason) = after.trim_start().strip_prefix(':') else {
            malformed.push((
                idx + 1,
                "lint:allow pragma must carry a reason: `// lint:allow(rule): reason`".to_string(),
            ));
            continue;
        };
        let reason = reason.trim();
        if reason.is_empty() {
            malformed
                .push((idx + 1, "lint:allow pragma must carry a non-empty reason".to_string()));
            continue;
        }
        // attach: the pragma's own line if it carries code, else the next
        // line that does
        let target = if !l.code.trim().is_empty() {
            Some(idx + 1)
        } else {
            lines
                .iter()
                .enumerate()
                .skip(idx + 1)
                .find(|(_, l2)| !l2.code.trim().is_empty())
                .map(|(j, _)| j + 1)
        };
        match target {
            Some(t) => pragmas.push(Pragma { rule, target: t, line: idx + 1 }),
            None => malformed.push((idx + 1, "lint:allow pragma targets no code".to_string())),
        }
    }
    (pragmas, malformed)
}
