//! Repo-local task runner, invoked as `cargo xtask <command>` via the
//! `[alias]` in `.cargo/config.toml`.
//!
//! Commands:
//! - `lint [--json] [src-root]` — run the in-repo invariant linter over the
//!   library sources (defaults to `rust/src`, located relative to this
//!   crate so it works from any working directory). Exits nonzero on any
//!   violation. With `--json`, stdout carries one JSON object per
//!   diagnostic (JSONL) and the summary count moves to stderr — the format
//!   the CI static-analysis job archives as an artifact.
//! - `bench [--out PATH]` — run the four bench drivers with the harness's
//!   JSON markers enabled and collect their records verbatim into the
//!   tracked trajectory file (`BENCH_<n>.json` at the repo root, or
//!   `PATH`). Honours `COFORMER_BENCH_QUICK=1`. Fails only on harness
//!   errors (a driver exiting nonzero or emitting no records), never on
//!   slow numbers.

mod bench;
mod lint;

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => {
            let mut json = false;
            let mut root = None;
            for a in args {
                if a == "--json" {
                    json = true;
                } else {
                    root = Some(PathBuf::from(a));
                }
            }
            let root = root.unwrap_or_else(default_src_root);
            if !root.is_dir() {
                eprintln!("xtask lint: source root {} is not a directory", root.display());
                return ExitCode::from(2);
            }
            lint::run(&root, json)
        }
        Some("bench") => {
            let mut out = None;
            while let Some(a) = args.next() {
                if a == "--out" {
                    match args.next() {
                        Some(p) => out = Some(PathBuf::from(p)),
                        None => {
                            eprintln!("xtask bench: --out requires a path");
                            return ExitCode::from(2);
                        }
                    }
                } else {
                    eprintln!("xtask bench: unknown argument `{a}`");
                    return ExitCode::from(2);
                }
            }
            bench::run(out)
        }
        Some(other) => {
            eprintln!("xtask: unknown command `{other}` (available: lint, bench)");
            ExitCode::from(2)
        }
        None => {
            eprintln!(
                "usage: cargo xtask lint [--json] [src-root] | cargo xtask bench [--out PATH]"
            );
            ExitCode::from(2)
        }
    }
}

/// The library sources live at `rust/src`, one level up from this crate's
/// manifest (`rust/xtask`) — resolved at compile time so the tool is
/// independent of the invocation directory.
fn default_src_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("src"))
        .unwrap_or_else(|| PathBuf::from("rust/src"))
}
