//! `cargo xtask bench` — run the four bench drivers and collect their
//! `BENCH_JSON` machine lines into one tracked trajectory file
//! (`BENCH_<n>.json` at the repo root).
//!
//! Protocol: each driver is run through `cargo bench -p coformer --bench
//! <suite>` with `COFORMER_BENCH_JSON=1` and `COFORMER_BENCH_SUITE=<suite>`
//! set, so every `metrics::bench::bench` call (and every artifact-gated
//! section's `skip_marker`) prints a one-line JSON record prefixed
//! `BENCH_JSON ` alongside its human-readable line. This runner passes
//! those records through **verbatim** — the numbers land in the file from
//! the exact code that computed them, and this crate stays
//! dependency-free (no JSON parser; the records are already JSON).
//!
//! `COFORMER_BENCH_QUICK=1` is honoured by the harness itself (clamped
//! warmup/iters); the runner just inherits it and records which mode the
//! file was produced in.
//!
//! Failure model: a driver exiting nonzero, or producing zero `BENCH_JSON`
//! records, is a harness error and fails the run. Slow or noisy numbers
//! never do — the trajectory tracks them, it does not judge them.

use std::path::{Path, PathBuf};
use std::process::{Command, ExitCode};

/// The four bench drivers, in the order they appear in `rust/benches/`
/// docs and CI. Artifact-gated sections inside them self-skip (and emit
/// skip records) — the suite list here never changes with artifact state.
const SUITES: [&str; 4] = ["coordinator", "debo", "runtime", "strategies"];

pub fn run(out_override: Option<PathBuf>) -> ExitCode {
    let repo_root = repo_root();
    let mut entries: Vec<String> = Vec::new();
    for suite in SUITES {
        eprintln!("xtask bench: running suite `{suite}`");
        let output = Command::new(cargo())
            .args(["bench", "-p", "coformer", "--bench", suite])
            .env("COFORMER_BENCH_JSON", "1")
            .env("COFORMER_BENCH_SUITE", suite)
            .current_dir(&repo_root)
            .output();
        let output = match output {
            Ok(o) => o,
            Err(e) => {
                eprintln!("xtask bench: failed to spawn cargo for `{suite}`: {e}");
                return ExitCode::from(2);
            }
        };
        let stdout = String::from_utf8_lossy(&output.stdout);
        // echo the human-readable lines so the run stays scannable
        print!("{stdout}");
        eprint!("{}", String::from_utf8_lossy(&output.stderr));
        if !output.status.success() {
            eprintln!("xtask bench: suite `{suite}` exited with {}", output.status);
            return ExitCode::from(2);
        }
        let before = entries.len();
        for line in stdout.lines() {
            if let Some(json) = line.strip_prefix("BENCH_JSON ") {
                entries.push(json.trim().to_string());
            }
        }
        if entries.len() == before {
            eprintln!(
                "xtask bench: suite `{suite}` produced no BENCH_JSON records \
                 (harness wiring broken?)"
            );
            return ExitCode::from(2);
        }
    }

    let out_path = out_override.unwrap_or_else(|| trajectory_path(&repo_root));
    let doc = assemble(&repo_root, &entries);
    if let Err(e) = std::fs::write(&out_path, doc) {
        eprintln!("xtask bench: failed to write {}: {e}", out_path.display());
        return ExitCode::from(2);
    }
    eprintln!(
        "xtask bench: wrote {} ({} entries from {} suites)",
        out_path.display(),
        entries.len(),
        SUITES.len()
    );
    ExitCode::SUCCESS
}

/// Assemble the trajectory document by string concatenation: the entries
/// are verbatim JSON lines from the harness, so the only JSON this runner
/// authors is the constant header scaffolding.
fn assemble(repo_root: &Path, entries: &[String]) -> String {
    let quick = std::env::var("COFORMER_BENCH_QUICK").as_deref() == Ok("1");
    let sha = git_sha(repo_root);
    let suites = SUITES
        .iter()
        .map(|s| format!("\"{s}\""))
        .collect::<Vec<_>>()
        .join(", ");
    let mut doc = String::new();
    doc.push_str("{\n");
    doc.push_str("  \"schema\": \"coformer-bench-v1\",\n");
    doc.push_str(&format!("  \"git_sha\": \"{sha}\",\n"));
    doc.push_str(&format!("  \"quick\": {quick},\n"));
    doc.push_str("  \"provenance\": \"measured\",\n");
    doc.push_str(&format!("  \"suites\": [{suites}],\n"));
    doc.push_str("  \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        let sep = if i + 1 == entries.len() { "" } else { "," };
        doc.push_str(&format!("    {e}{sep}\n"));
    }
    doc.push_str("  ]\n");
    doc.push_str("}\n");
    doc
}

/// The tracked file for *this* PR refreshes the highest-indexed
/// `BENCH_<n>.json` already at the repo root (the trajectory keeps one
/// file per PR; a re-run within a PR overwrites, never appends), starting
/// at `BENCH_10.json` when none exists yet.
fn trajectory_path(repo_root: &Path) -> PathBuf {
    let mut best: Option<(u32, PathBuf)> = None;
    if let Ok(rd) = std::fs::read_dir(repo_root) {
        for entry in rd.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(idx) = name
                .strip_prefix("BENCH_")
                .and_then(|r| r.strip_suffix(".json"))
                .and_then(|n| n.parse::<u32>().ok())
            {
                if best.as_ref().map_or(true, |(b, _)| idx > *b) {
                    best = Some((idx, entry.path()));
                }
            }
        }
    }
    match best {
        Some((_, p)) => p,
        None => repo_root.join("BENCH_10.json"),
    }
}

fn git_sha(repo_root: &Path) -> String {
    Command::new("git")
        .args(["rev-parse", "HEAD"])
        .current_dir(repo_root)
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn cargo() -> String {
    std::env::var("CARGO").unwrap_or_else(|_| "cargo".to_string())
}

/// The repo root is two levels up from this crate's manifest
/// (`rust/xtask`) — resolved at compile time so the tool is independent
/// of the invocation directory.
fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(Path::parent)
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assemble_wraps_verbatim_entries_in_the_header() {
        let entries = vec![
            r#"{"bench": "debo", "name": "a", "iters": 3, "mean_ns": 1, "p50_ns": 1, "p95_ns": 2}"#
                .to_string(),
            r#"{"bench": "runtime", "name": "runtime_suite", "skipped": true, "reason": "x"}"#
                .to_string(),
        ];
        let doc = assemble(Path::new("/nonexistent-repo-root"), &entries);
        assert!(doc.contains("\"schema\": \"coformer-bench-v1\""));
        assert!(doc.contains("\"provenance\": \"measured\""));
        assert!(doc.contains("\"git_sha\": \"unknown\""));
        assert!(doc.contains(&entries[0]));
        assert!(doc.contains(&entries[1]));
        // entries are comma-separated, last entry bare
        assert!(doc.contains("p95_ns\": 2},\n"));
        assert!(doc.contains("\"reason\": \"x\"}\n"));
    }

    #[test]
    fn trajectory_path_picks_highest_index_or_defaults() {
        let dir = std::env::temp_dir().join(format!("bench-xtask-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(trajectory_path(&dir), dir.join("BENCH_10.json"));
        std::fs::write(dir.join("BENCH_10.json"), "{}").unwrap();
        std::fs::write(dir.join("BENCH_12.json"), "{}").unwrap();
        assert_eq!(trajectory_path(&dir), dir.join("BENCH_12.json"));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
