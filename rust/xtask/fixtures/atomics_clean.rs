use std::sync::atomic::{AtomicUsize, Ordering};

pub fn bump(c: &AtomicUsize) -> usize {
    c.fetch_add(1, Ordering::SeqCst)
}

pub fn relaxed_counter(c: &AtomicUsize) -> usize {
    // lint:allow(atomics-ordering): fixture stat counter, no ordering needed
    c.fetch_add(1, Ordering::Relaxed)
}
