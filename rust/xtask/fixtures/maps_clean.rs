use std::collections::BTreeMap;

pub fn stable_order() -> Vec<String> {
    let m: BTreeMap<String, u32> = BTreeMap::new();
    m.into_keys().collect()
}
