// lint:allow(no-panic-in-lib): nothing here actually panics
pub fn tidy() {}
