//! Fixture: four `units` violations — two unsuffixed quantity bindings,
//! then two conversion literals (rustfmt-normalized spacing).

pub struct Window {
    pub deadline: f64,
    pub latency: f64,
}

pub fn to_ms(x: f64) -> f64 {
    x * 1e3
}

pub fn payload_bits(n: f64) -> f64 {
    n * 8.0
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let energy = 2.0; // untyped f64 in tests never trips the rule
        assert_eq!(super::to_ms(energy), 2e3);
    }
}
