pub fn justified(v: Option<u32>) -> u32 {
    // lint:allow(no-panic-in-lib): fixture-documented invariant makes None
    // impossible here
    v.unwrap()
}
