use std::collections::HashMap;

pub fn leaky_order() -> Vec<String> {
    let m: HashMap<String, u32> = HashMap::new();
    m.into_keys().collect()
}
