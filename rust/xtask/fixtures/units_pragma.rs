//! Fixture: `units` violations suppressed by pragmas — one on a bare
//! quantity field, one inline on a conversion literal.

pub struct WireRecord {
    // lint:allow(units): legacy wire-format field; unit fixed by the peer protocol
    pub latency: f64,
}

pub fn to_micros(x: f64) -> f64 {
    x * 1e6 // lint:allow(units): fixture exercises an inline pragma'd conversion
}
