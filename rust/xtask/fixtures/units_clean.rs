//! Fixture: quantity bindings with unit suffixes and no conversion
//! literals — fully `units`-clean.

pub struct Window {
    pub deadline_ms: f64,
    pub latency_s: f64,
    pub bandwidth_mbps: f64,
    pub energy_budget_j: f64,
    /// Dimensionless multiplier on a quantity: `factor` is an accepted
    /// marker, as are `frac` and `slack`.
    pub deadline_factor: f64,
}

pub fn slowest(latency_samples_ms: &[f64]) -> f64 {
    latency_samples_ms.iter().cloned().fold(0.0, f64::max)
}

pub fn scaled_deadline_ms(deadline_ms: f64, factor: f64) -> f64 {
    deadline_ms * factor
}
