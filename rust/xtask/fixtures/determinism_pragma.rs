pub fn telemetry_only() -> std::time::Instant {
    // lint:allow(determinism): fixture telemetry site, never scheduling
    std::time::Instant::now()
}
