use std::time::SystemTime;

pub fn stamp() -> f64 {
    let t0 = std::time::Instant::now();
    let _ = SystemTime::now();
    t0.elapsed().as_secs_f64()
}
