pub struct SystemConfig {
    pub fault: FaultPolicy,
    pub nested: NestedConfig,
}

pub struct NestedConfig {
    pub energy: EnergyPolicy,
}

pub struct FaultPolicy {
    pub min_quorum: usize,
}

pub struct EnergyPolicy {
    pub budget_j: f64,
}

impl SystemConfig {
    pub fn validate(&self) -> Result<(), String> {
        self.fault.validate()?;
        self.nested.validate()
    }
}

impl NestedConfig {
    pub fn validate(&self) -> Result<(), String> {
        self.energy.validate()
    }
}

impl FaultPolicy {
    pub fn validate(&self) -> Result<(), String> {
        Ok(())
    }
}

impl EnergyPolicy {
    pub fn validate(&self) -> Result<(), String> {
        Ok(())
    }
}
