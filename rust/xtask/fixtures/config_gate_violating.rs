pub struct SystemConfig {
    pub fault: FaultPolicy,
    pub orphan: OrphanPolicy,
}

pub struct FaultPolicy {
    pub min_quorum: usize,
}

pub struct OrphanPolicy {
    pub knob: usize,
}

impl SystemConfig {
    pub fn validate(&self) -> Result<(), String> {
        self.fault.validate()
    }
}

impl FaultPolicy {
    pub fn validate(&self) -> Result<(), String> {
        Ok(())
    }
}

impl OrphanPolicy {
    pub fn validate(&self) -> Result<(), String> {
        Ok(())
    }
}
