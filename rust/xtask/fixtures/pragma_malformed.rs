// lint:allow(no-such-rule): bogus rule name
pub fn fine() {}

// lint:allow(no-panic-in-lib)
pub fn missing_reason() {}
