pub fn careful(v: Option<u32>) -> Result<u32, String> {
    v.ok_or_else(|| "missing".to_string())
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        assert_eq!(super::careful(Some(3)).unwrap(), 3);
    }
}
