//! A minimal, dependency-free stand-in for the `loom` model checker.
//!
//! The real `loom` crate explores every weak-memory interleaving of a test
//! body. This vendored substitute explores every **sequentially consistent**
//! interleaving instead: execution is serialized onto one runnable thread at
//! a time, a schedule decision is taken before every atomic operation, and a
//! depth-first search over the decision tape replays the body until the
//! schedule space is exhausted (or a property panics, which is surfaced as a
//! counterexample).
//!
//! Soundness for this repository: the `atomics-ordering` lint (`cargo xtask
//! lint`) pins every `Admission` atomic to `Ordering::SeqCst`, and under
//! `SeqCst` the set of observable behaviours *is* the set of sequentially
//! consistent interleavings — so exhausting them is a complete model check
//! for the admission plane, not an approximation.
//!
//! Supported surface (what `rust/tests/loom_admission.rs` needs):
//!
//! * [`model`] — run a closure under exhaustive schedule exploration
//! * [`thread::spawn`] / [`thread::JoinHandle`] / [`thread::yield_now`]
//! * [`sync::Arc`] (re-export of `std::sync::Arc`)
//! * [`sync::atomic::AtomicUsize`] / [`sync::atomic::Ordering`]
//!
//! Blocking primitives (channels, mutex parking) are intentionally absent:
//! the admission gate is lock-free, which is exactly why it needs a model
//! checker rather than a mutex argument.

mod rt;
pub mod sync;
pub mod thread;

/// Run `f` once per distinct thread interleaving until the schedule space is
/// exhausted. Panics (with the original payload) as soon as any interleaving
/// makes the body panic, i.e. when a property assertion finds a
/// counterexample.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    rt::model(f);
}

#[cfg(test)]
mod tests {
    use super::sync::atomic::{AtomicUsize, Ordering};
    use super::sync::Arc;
    use super::thread;

    /// Atomic RMW ops are atomic under every schedule: two `fetch_add`s
    /// always sum — and the driver must actually explore more than one
    /// schedule to say so.
    #[test]
    fn explores_schedules_and_conserves_fetch_add() {
        let iterations = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let seen = std::sync::Arc::clone(&iterations);
        super::model(move || {
            seen.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            let a = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let a = Arc::clone(&a);
                    thread::spawn(move || {
                        a.fetch_add(1, Ordering::SeqCst);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(a.load(Ordering::SeqCst), 2);
        });
        assert!(
            iterations.load(std::sync::atomic::Ordering::SeqCst) >= 2,
            "driver must explore more than one interleaving"
        );
    }

    /// A deliberately racy load-then-store increment: some interleaving
    /// loses an update, and the checker must find it and fail the model.
    #[test]
    #[should_panic]
    fn finds_lost_update_counterexample() {
        super::model(|| {
            let a = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let a = Arc::clone(&a);
                    thread::spawn(move || {
                        let v = a.load(Ordering::SeqCst);
                        a.store(v + 1, Ordering::SeqCst);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(a.load(Ordering::SeqCst), 2, "racy RMW loses an update");
        });
    }

    /// Threads spawned outside `model()` just run: schedule points are
    /// no-ops without a scheduler, so library code compiled against these
    /// types stays usable from plain tests.
    #[test]
    fn atomics_work_outside_a_model() {
        let a = AtomicUsize::new(40);
        assert_eq!(a.fetch_add(2, Ordering::SeqCst), 40);
        assert_eq!(a.load(Ordering::SeqCst), 42);
        assert_eq!(a.fetch_sub(2, Ordering::SeqCst), 42);
        assert_eq!(a.swap(7, Ordering::SeqCst), 40);
        assert_eq!(a.compare_exchange(7, 9, Ordering::SeqCst, Ordering::SeqCst), Ok(7));
        assert_eq!(a.into_inner(), 9);
    }
}
