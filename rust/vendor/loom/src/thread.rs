//! `loom::thread` — model-checked threads.

use crate::rt;
use crate::rt::Slot;

/// Handle to a model thread, mirroring `std::thread::JoinHandle`.
pub struct JoinHandle<T> {
    sched: std::sync::Arc<rt::Scheduler>,
    id: usize,
    slot: Slot<T>,
}

impl<T> JoinHandle<T> {
    pub(crate) fn new(sched: std::sync::Arc<rt::Scheduler>, id: usize, slot: Slot<T>) -> Self {
        JoinHandle { sched, id, slot }
    }

    /// Block (in model time) until the thread finishes; returns its output
    /// or the panic payload, like `std::thread::JoinHandle::join`.
    pub fn join(self) -> std::thread::Result<T> {
        rt::join_thread(&self.sched, self.id);
        self.slot
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .take()
            .expect("loom: thread retired without a result (model failure)")
    }
}

/// Spawn a model thread. Must be called inside [`crate::model`].
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    rt::spawn(f)
}

/// Explicit schedule point (no-op outside a model).
pub fn yield_now() {
    rt::yield_point();
}
