//! `loom::sync` — model-checked shared-memory primitives.

pub use std::sync::Arc;

pub mod atomic {
    //! Atomics whose every operation is a schedule point.
    //!
    //! Operations always execute with `SeqCst` semantics regardless of the
    //! ordering argument (see the crate docs for why that is sound here:
    //! the `atomics-ordering` lint pins call sites to `SeqCst` anyway).
    //! `fetch_sub` additionally panics on underflow even in release builds,
    //! so a lost-permit bug shows up as a deterministic counterexample
    //! rather than a silent wrap to `usize::MAX`.

    use std::sync::atomic::Ordering as StdOrdering;

    use crate::rt;

    pub use std::sync::atomic::Ordering;

    /// Model-checked `AtomicUsize`. Because the scheduler runs exactly one
    /// model thread at a time, a load/store pair between two schedule
    /// points is atomic with respect to the model.
    #[derive(Debug, Default)]
    pub struct AtomicUsize {
        inner: std::sync::atomic::AtomicUsize,
    }

    impl AtomicUsize {
        pub fn new(v: usize) -> AtomicUsize {
            AtomicUsize { inner: std::sync::atomic::AtomicUsize::new(v) }
        }

        pub fn load(&self, _order: Ordering) -> usize {
            rt::yield_point();
            self.inner.load(StdOrdering::SeqCst)
        }

        pub fn store(&self, v: usize, _order: Ordering) {
            rt::yield_point();
            self.inner.store(v, StdOrdering::SeqCst);
        }

        pub fn swap(&self, v: usize, _order: Ordering) -> usize {
            rt::yield_point();
            self.inner.swap(v, StdOrdering::SeqCst)
        }

        pub fn fetch_add(&self, v: usize, _order: Ordering) -> usize {
            rt::yield_point();
            self.inner.fetch_add(v, StdOrdering::SeqCst)
        }

        pub fn fetch_sub(&self, v: usize, _order: Ordering) -> usize {
            rt::yield_point();
            let prev = self.inner.load(StdOrdering::SeqCst);
            let next = prev
                .checked_sub(v)
                .expect("loom: AtomicUsize::fetch_sub underflow (lost permit)");
            self.inner.store(next, StdOrdering::SeqCst);
            prev
        }

        pub fn fetch_max(&self, v: usize, _order: Ordering) -> usize {
            rt::yield_point();
            self.inner.fetch_max(v, StdOrdering::SeqCst)
        }

        /// One atomic read-modify-write with one schedule point, matching
        /// the model's granularity for every other single operation: the
        /// closure sees the value at this schedule point and no other
        /// thread runs between the read and the conditional store.
        pub fn fetch_update<F>(
            &self,
            _set_order: Ordering,
            _fetch_order: Ordering,
            mut f: F,
        ) -> Result<usize, usize>
        where
            F: FnMut(usize) -> Option<usize>,
        {
            rt::yield_point();
            let prev = self.inner.load(StdOrdering::SeqCst);
            match f(prev) {
                Some(next) => {
                    self.inner.store(next, StdOrdering::SeqCst);
                    Ok(prev)
                }
                None => Err(prev),
            }
        }

        pub fn compare_exchange(
            &self,
            current: usize,
            new: usize,
            _success: Ordering,
            _failure: Ordering,
        ) -> Result<usize, usize> {
            rt::yield_point();
            self.inner.compare_exchange(current, new, StdOrdering::SeqCst, StdOrdering::SeqCst)
        }

        pub fn into_inner(self) -> usize {
            self.inner.into_inner()
        }
    }
}
