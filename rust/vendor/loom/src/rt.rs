//! Cooperative exhaustive scheduler.
//!
//! One OS thread is runnable at a time; everyone else parks on a condvar.
//! Each atomic operation (and `thread::yield_now` / `thread::spawn`) is a
//! *schedule point*: the running thread picks who runs next. When more than
//! one thread is runnable the decision is recorded on a tape
//! (`Choice { chosen, alternatives }`); the driver replays a tape prefix and
//! advances the rightmost incrementable choice, which is a depth-first walk
//! of the full schedule tree. A run with no incrementable choice left means
//! the space is exhausted.
//!
//! Failure handling: the first panic in any thread flips `failed`, which
//! wakes every parked thread so the whole iteration unwinds; the driver then
//! resumes the original payload on the test thread. If no thread is runnable
//! but some are unfinished, the detecting thread reports a deadlock.

use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::thread::JoinHandle;

const DEFAULT_MAX_ITERATIONS: u64 = 2_000_000;

/// Where a thread's closure output (or panic payload) is parked for `join`.
pub(crate) type Slot<T> = Arc<Mutex<Option<std::thread::Result<T>>>>;

#[derive(Clone, Copy, Debug)]
struct Choice {
    chosen: usize,
    alternatives: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Run {
    Runnable,
    BlockedOnJoin(usize),
    Finished,
}

struct State {
    threads: Vec<Run>,
    /// Index of the one thread allowed to execute (`usize::MAX` = iteration
    /// over, nobody scheduled).
    current: usize,
    tape: Vec<Choice>,
    /// Next tape index to consume (replay) or append (explore).
    depth: usize,
    /// Set on the first panic or deadlock; tears the iteration down.
    failed: bool,
    handles: Vec<std::thread::JoinHandle<()>>,
}

pub(crate) struct Scheduler {
    state: Mutex<State>,
    cv: Condvar,
}

thread_local! {
    static CURRENT: RefCell<Option<(Arc<Scheduler>, usize)>> = const { RefCell::new(None) };
}

fn lock(m: &Mutex<State>) -> MutexGuard<'_, State> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn current() -> Option<(Arc<Scheduler>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Pick the next thread to run among the runnable ones, recording or
/// replaying a tape decision when there is a real choice.
fn pick_next(st: &mut State) -> Option<usize> {
    let candidates: Vec<usize> = st
        .threads
        .iter()
        .enumerate()
        .filter(|(_, r)| **r == Run::Runnable)
        .map(|(i, _)| i)
        .collect();
    match candidates.len() {
        0 => None,
        1 => Some(candidates[0]),
        n => {
            let idx = if st.depth < st.tape.len() {
                let c = st.tape[st.depth];
                assert!(
                    c.alternatives == n && c.chosen < n,
                    "loom: execution diverged from the recorded schedule \
                     (is the model body deterministic?)"
                );
                c.chosen
            } else {
                st.tape.push(Choice { chosen: 0, alternatives: n });
                0
            };
            st.depth += 1;
            Some(candidates[idx])
        }
    }
}

impl Scheduler {
    fn new(tape: Vec<Choice>) -> Scheduler {
        Scheduler {
            state: Mutex::new(State {
                threads: vec![Run::Runnable],
                current: 0,
                tape,
                depth: 0,
                failed: false,
                handles: Vec::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Schedule point: let the tape decide who executes the next step
    /// (possibly the caller itself, i.e. no preemption).
    fn switch(&self, me: usize) {
        let mut st = lock(&self.state);
        if st.failed {
            drop(st);
            panic!("loom: model failed on another thread");
        }
        let next = match pick_next(&mut st) {
            Some(next) => next,
            None => {
                st.failed = true;
                self.cv.notify_all();
                drop(st);
                panic!("loom: deadlock — no runnable thread at a schedule point");
            }
        };
        if next == me {
            return;
        }
        st.current = next;
        self.cv.notify_all();
        while st.current != me && !st.failed {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.failed {
            drop(st);
            panic!("loom: model failed on another thread");
        }
    }

    /// Park a freshly spawned thread until it is first scheduled. Returns
    /// `false` when the iteration failed before the thread ever ran.
    fn wait_for_turn(&self, me: usize) -> bool {
        let mut st = lock(&self.state);
        while st.current != me && !st.failed {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        !st.failed
    }

    /// Thread retirement: unblock joiners and hand the token onward (or,
    /// on panic, tear the whole iteration down).
    fn finish(&self, me: usize, panicked: bool) {
        let mut st = lock(&self.state);
        st.threads[me] = Run::Finished;
        for r in st.threads.iter_mut() {
            if *r == Run::BlockedOnJoin(me) {
                *r = Run::Runnable;
            }
        }
        if panicked {
            st.failed = true;
        }
        if st.failed {
            self.cv.notify_all();
            return;
        }
        match pick_next(&mut st) {
            Some(next) => {
                st.current = next;
                self.cv.notify_all();
            }
            None => {
                if st.threads.iter().all(|r| *r == Run::Finished) {
                    st.current = usize::MAX;
                    self.cv.notify_all();
                } else {
                    st.failed = true;
                    self.cv.notify_all();
                    drop(st);
                    panic!("loom: deadlock — every unfinished thread is blocked");
                }
            }
        }
    }

    /// Block the calling model thread until `target` finishes.
    fn join_thread(&self, me: usize, target: usize) {
        loop {
            let mut st = lock(&self.state);
            if st.failed {
                drop(st);
                panic!("loom: model failed on another thread");
            }
            if st.threads[target] == Run::Finished {
                return;
            }
            st.threads[me] = Run::BlockedOnJoin(target);
            match pick_next(&mut st) {
                Some(next) => {
                    st.current = next;
                    self.cv.notify_all();
                }
                None => {
                    st.failed = true;
                    self.cv.notify_all();
                    drop(st);
                    panic!("loom: deadlock — join cycle with no runnable thread");
                }
            }
            while st.current != me && !st.failed {
                st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            if st.failed {
                drop(st);
                panic!("loom: model failed on another thread");
            }
            // Woken as a runnable thread again: re-check why (the target
            // finishing is the only unblocker, so the next pass returns).
        }
    }
}

/// Schedule point for the calling thread, if it is a model thread. Atomic
/// ops outside `model()` (e.g. library code compiled under `cfg(loom)` but
/// driven by a plain test) just execute without interleaving exploration.
pub(crate) fn yield_point() {
    if let Some((sched, me)) = current() {
        sched.switch(me);
    }
}

/// `loom::thread::spawn` backend: register the thread, park it until first
/// scheduled, and treat the spawn itself as a schedule point.
pub(crate) fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let (sched, me) = current().expect("loom: thread::spawn outside of loom::model");
    let slot: Slot<T> = Arc::new(Mutex::new(None));
    let id = {
        let mut st = lock(&sched.state);
        st.threads.push(Run::Runnable);
        st.threads.len() - 1
    };
    let thread_slot = Arc::clone(&slot);
    let thread_sched = Arc::clone(&sched);
    let os_handle = std::thread::spawn(move || {
        CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&thread_sched), id)));
        if thread_sched.wait_for_turn(id) {
            let out = catch_unwind(AssertUnwindSafe(f));
            let panicked = out.is_err();
            *lock_slot(&thread_slot) = Some(out);
            thread_sched.finish(id, panicked);
        } else {
            // Iteration already failed; retire without running the body.
            thread_sched.finish(id, false);
        }
    });
    lock(&sched.state).handles.push(os_handle);
    sched.switch(me);
    JoinHandle::new(sched, id, slot)
}

fn lock_slot<T>(
    slot: &Mutex<Option<std::thread::Result<T>>>,
) -> MutexGuard<'_, Option<std::thread::Result<T>>> {
    slot.lock().unwrap_or_else(|e| e.into_inner())
}

/// `JoinHandle::join` backend.
pub(crate) fn join_thread(sched: &Scheduler, target: usize) {
    let (_, me) = current().expect("loom: JoinHandle::join outside of loom::model");
    sched.join_thread(me, target);
}

/// Driver: depth-first search over the schedule tree.
pub(crate) fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let max_iterations = std::env::var("LOOM_MAX_ITERATIONS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(DEFAULT_MAX_ITERATIONS);
    let mut prefix: Vec<Choice> = Vec::new();
    let mut iterations: u64 = 0;
    loop {
        iterations += 1;
        assert!(
            iterations <= max_iterations,
            "loom: schedule space not exhausted after {max_iterations} iterations \
             (set LOOM_MAX_ITERATIONS to raise the bound)"
        );
        let sched = Arc::new(Scheduler::new(prefix.clone()));
        let body = Arc::clone(&f);
        let root_sched = Arc::clone(&sched);
        let root = std::thread::spawn(move || {
            CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&root_sched), 0)));
            let out = catch_unwind(AssertUnwindSafe(|| (*body)()));
            let panicked = out.is_err();
            root_sched.finish(0, panicked);
            out
        });
        let mut failure = match root.join() {
            Ok(Ok(())) => None,
            Ok(Err(payload)) => Some(payload),
            Err(payload) => Some(payload),
        };
        // Drain every OS thread this iteration spawned (they all exit once
        // the iteration completes or `failed` is set).
        loop {
            let handles = std::mem::take(&mut lock(&sched.state).handles);
            if handles.is_empty() {
                break;
            }
            for h in handles {
                if let Err(payload) = h.join() {
                    failure.get_or_insert(payload);
                }
            }
        }
        if let Some(payload) = failure {
            eprintln!("loom: counterexample found on iteration {iterations}");
            resume_unwind(payload);
        }
        // Depth-first advance: bump the rightmost incrementable decision,
        // truncating everything after it.
        let mut tape = lock(&sched.state).tape.clone();
        let mut advanced = false;
        while let Some(c) = tape.pop() {
            if c.chosen + 1 < c.alternatives {
                tape.push(Choice { chosen: c.chosen + 1, alternatives: c.alternatives });
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
        prefix = tape;
    }
}
