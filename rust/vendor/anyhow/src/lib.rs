//! Minimal vendored implementation of the `anyhow` API surface this
//! workspace uses: `Error`, `Result`, the `anyhow!` / `bail!` / `ensure!`
//! macros, and typed-error recovery via [`Error::new`] + `downcast_ref`
//! (the serving stack's `Overloaded` admission error depends on it).
//! Error sources are flattened into the message at conversion time, so
//! `{}`, `{:#}` and `{:?}` all render the full text; errors converted from
//! a concrete `std::error::Error` additionally keep the original value for
//! `downcast_ref`, matching the real anyhow's contract.

use std::any::Any;
use std::fmt;

/// A string-backed error value, convertible from any `std::error::Error`,
/// optionally carrying the original typed error for `downcast_ref`.
pub struct Error {
    msg: String,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string(), payload: None }
    }

    /// Construct from a concrete error value, keeping it for
    /// [`Error::downcast_ref`].
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Self {
        // Flatten the source chain into one message.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            let text = s.to_string();
            if !msg.contains(&text) {
                msg.push_str(": ");
                msg.push_str(&text);
            }
            src = s.source();
        }
        Error { msg, payload: Some(Box::new(e)) }
    }

    /// Borrow the original typed error, if this `Error` was built from one
    /// via [`Error::new`] or the blanket `From` conversion.
    pub fn downcast_ref<T: Any>(&self) -> Option<&T> {
        self.payload.as_ref()?.downcast_ref::<T>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket conversion legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::new(e)
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("bad value {}", 3);
        assert_eq!(e.to_string(), "bad value 3");
        assert_eq!(format!("{e:#}"), "bad value 3");
        assert!(fails(true).is_ok());
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn converts_std_errors() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
    }

    #[derive(Clone, Debug, PartialEq)]
    struct Typed {
        code: u32,
    }

    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.code)
        }
    }

    impl std::error::Error for Typed {}

    #[test]
    fn downcast_recovers_typed_errors() {
        let e = Error::new(Typed { code: 7 });
        assert_eq!(e.to_string(), "typed error 7");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed { code: 7 }));
        assert!(e.downcast_ref::<std::io::Error>().is_none());

        // the blanket `?` conversion keeps the payload too
        let via_from: Error = Typed { code: 9 }.into();
        assert_eq!(via_from.downcast_ref::<Typed>().unwrap().code, 9);

        // message-built errors have no payload
        assert!(anyhow!("plain").downcast_ref::<Typed>().is_none());
    }
}
