//! API-compatible stub of the `xla` PJRT bindings used by the coformer
//! runtime. Host-side literal plumbing ([`Literal`], shapes, tuples) is
//! fully functional pure rust; device execution ([`PjRtLoadedExecutable::
//! execute`]) returns a clean error, so every artifact-driven path fails
//! gracefully when the real backend is absent (integration suites already
//! skip when `artifacts/` is not built). Swap this path dependency for the
//! real bindings to run compiled HLO.

use std::fmt;

/// Stub error type (mirrors `xla::Error` as far as callers care).
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the workspace moves across the boundary.
#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Sealed-ish marker for supported native element types.
pub trait NativeType: Copy {
    fn into_data(v: Vec<Self>) -> Data;
    fn from_data(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-side literal: flat data + dims (or a tuple of literals).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::into_data(v.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { dims: vec![], data: T::into_data(vec![x]) }
    }

    /// Tuple literal (what executables return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![], data: Data::Tuple(parts) }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    /// Reinterpret the flat data under new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.len() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the elements out as `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Array shape (errors on tuple literals).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("tuple literal has no array shape".into()));
        }
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Dims of an array-shaped literal.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module handle. The stub validates the file exists and is
/// readable but does not interpret it.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path)
            .map_err(|e| Error(format!("cannot read HLO text {path}: {e}")))?;
        Ok(HloModuleProto)
    }
}

/// Computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (stub: holds the host literal).
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// Compiled executable handle. Execution is unavailable in the stub.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(
            "stub backend: HLO execution requires the real xla/PJRT bindings \
             (see rust/vendor/xla)"
                .into(),
        ))
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable)
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn tuple_destructures() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![2]);
    }

    #[test]
    fn execution_is_a_clean_error() {
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation::from_proto(&HloModuleProto)).unwrap();
        let err = exe.execute(&[]).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
