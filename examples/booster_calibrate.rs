//! Rust-driven boosting distillation (Algorithm 1 lines 12–15): calibrate
//! the edgenet_3dev members via the AOT train-step artifacts — Python is
//! not involved.
//!
//! ```text
//! cargo run --release --example booster_calibrate [steps]
//! ```

use coformer::booster::{BoostConfig, Booster};
use coformer::runtime::Engine;
use coformer::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(40);
    let engine = Engine::load("artifacts")?;
    println!("== booster: progressive distillation over AOT train steps ==");
    let booster = Booster::new(
        &engine,
        BoostConfig { steps, seed: 7, log_every: (steps / 4).max(1) },
    );
    let reports = booster.calibrate_deployment("edgenet_3dev")?;
    for r in &reports {
        println!(
            "{}: loss {:.4} → {:.4} over {steps} steps (per-sample {:.4})",
            r.model, r.first_loss, r.last_loss, r.mean_per_sample_loss
        );
        assert!(
            r.last_loss.is_finite(),
            "train step produced non-finite loss"
        );
    }
    println!("booster OK: weights resumed from deployment, refined in rust");
    Ok(())
}
