//! Quickstart: load the AOT artifacts, run one collaborative inference by
//! hand (sub-models → feature aggregation), and print the prediction.
//!
//! Run after `make artifacts && cargo build --release`:
//! ```text
//! cargo run --release --example quickstart
//! ```

use coformer::data::Dataset;
use coformer::model::Arch;
use coformer::runtime::engine::XBatch;
use coformer::runtime::Engine;
use coformer::Result;

fn main() -> Result<()> {
    // 1. Load the engine over the artifacts directory (PJRT CPU client +
    //    manifest; executables compile lazily).
    let engine = Engine::load("artifacts")?;
    let m = engine.manifest().clone();
    println!(
        "manifest: {} models, {} deployments (fast_build={})",
        m.models.len(),
        m.deployments.len(),
        m.fast_build
    );

    // 2. Pick the paper's primary deployment: 3 decomposed sub-models of
    //    the edgenet teacher, plus the Eq. 2 MLP aggregator.
    let dep = m.deployment("edgenet_3dev")?.clone();
    let task = m.task(&dep.task)?.clone();
    let ds = Dataset::load(std::path::Path::new("artifacts"), &task.splits["test"])?;
    println!("deployment {:?}: members {:?}", "edgenet_3dev", dep.members);

    // 3. Run a tiny batch through every sub-model (Phase 1), collect the
    //    downsampled features each device would transmit (Phase 2)...
    let n = 8usize;
    let idx: Vec<usize> = (0..n).collect();
    let mut shape = ds.x_shape.clone();
    shape[0] = n;
    let x = XBatch::F32 { data: ds.gather_x_f32(&idx), shape };
    let mut feats = Vec::new();
    for name in &dep.members {
        let out = engine.run_model(name, &x)?;
        let arch: &Arch = &m.model(name)?.arch;
        println!(
            "  {name}: features {:?} ({} bytes on the wire per sample)",
            out.feats_shape,
            arch.feature_bytes()
        );
        feats.push((out.feats, out.feats_shape));
    }

    // 4. ...and aggregate at the central node (Phase 3).
    let (logits, logits_shape) = engine.run_aggregator("edgenet_3dev", "mlp", &feats)?;
    let classes = logits_shape[1];
    let mut correct = 0;
    for i in 0..n {
        let row = &logits[i * classes..(i + 1) * classes];
        let pred = coformer::metrics::argmax(row);
        let label = ds.y[i];
        if pred as i32 == label {
            correct += 1;
        }
        println!("  sample {i}: predicted class {pred}, label {label}");
    }
    println!("quickstart: {correct}/{n} correct");
    Ok(())
}
