//! End-to-end validation driver (DESIGN.md §7): serve batched requests from
//! the synthetic test set through the full collaborative stack —
//! ExecServer (PJRT) → per-device worker threads → dynamic batcher →
//! Eq. 2 aggregation — and report accuracy, latency percentiles,
//! throughput and energy, vs the single-device teacher.
//!
//! ```text
//! cargo run --release --example serve_collaborative [n_requests]
//! ```

use coformer::config::{ElisionPolicy, FaultPolicy, ReplicationPolicy, SystemConfig};
use coformer::coordinator::{serve_all, RequestPayload, ServeBuilder};
use coformer::data::Dataset;
use coformer::device::DeviceProfile;
use coformer::model::{Arch, CostModel};
use coformer::runtime::ExecServer;
use coformer::strategies::registry::{CoFormer, SingleEdge};
use coformer::strategies::{DispatchMode, Scenario, Strategy, Sweep};
use coformer::Result;

fn main() -> Result<()> {
    let n_req: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(512);
    let artifacts = std::path::PathBuf::from("artifacts");

    // --- setup: engine thread, manifest, dataset -------------------------
    let server = ExecServer::start(artifacts.clone())?;
    let exec = server.handle();
    // manifest only — exactly one PJRT client per process (the server's)
    let m = coformer::runtime::Manifest::load(&artifacts)?;
    let dep = m.deployment("edgenet_3dev")?.clone();
    let task = m.task(&dep.task)?.clone();
    let ds = Dataset::load(&artifacts, &task.splits["test"])?;
    let n = n_req.min(ds.len());
    let archs: Vec<Arch> = dep
        .members
        .iter()
        .map(|name| m.model(name).map(|mm| mm.arch.clone()))
        .collect::<Result<_>>()?;

    // --- deploy: warm up executables + params (paper: deployed in advance)
    for member in &dep.members {
        exec.warmup(member)?;
    }
    // ServeBuilder (ISSUE 4): the positional start() pair replaced by
    // fluent setters; validation runs through SystemConfig::validate().
    // Fault policy: tolerate one straggler/death (2-of-3 quorum), 3× virtual
    // deadlines, hot re-dispatch of a dead device's sub-model.
    // Replication + admission control: one warm standby per member (a
    // primary death costs no aggregation arity while the replacement
    // warms), shedding past 1024 queued requests with a typed Overloaded
    // error as the surviving fleet's capacity shrinks. Elision makes the
    // standby dispatch load-adaptive: under sustained queue pressure the
    // fleet drops to primaries-only and re-banks the saved standby compute
    // as admission budget, restoring full replication when headroom
    // returns (unhealthy-primary members always keep their standbys).
    let coord = ServeBuilder::new(
        SystemConfig::paper_default(),
        exec,
        dep.clone(),
        archs,
        ds.x_stride(),
    )
    .fault(FaultPolicy { min_quorum: 2, ..FaultPolicy::default() })
    .replication(ReplicationPolicy {
        replicas: 2,
        elision: ElisionPolicy { enabled: true, ..ElisionPolicy::default() },
        ..ReplicationPolicy::default()
    })
    .start()?;
    let handle = coord.handle();

    // --- serve the split --------------------------------------------------
    let payloads: Vec<RequestPayload> =
        (0..n).map(|i| RequestPayload::F32(ds.gather_x_f32(&[i]))).collect();
    let t0 = std::time::Instant::now();
    let responses = serve_all(&handle, payloads)?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = coord.shutdown()?;

    let correct = responses
        .iter()
        .enumerate()
        .filter(|(i, r)| r.prediction as i32 == ds.y[*i])
        .count();
    println!("== CoFormer collaborative serving (edgenet_3dev, mlp aggregator) ==");
    println!("requests: {n}   batches: {} (mean batch {:.1})", stats.batches,
        stats.requests as f64 / stats.batches.max(1) as f64);
    println!("accuracy: {:.4} (build-time aggregated acc: {:.4})",
        correct as f64 / n as f64, dep.aggregators["mlp"].accuracy);
    println!(
        "virtual edge latency: p50 {:.2} ms  p95 {:.2} ms  mean {:.2} ± {:.2} ms",
        stats.virtual_latency.p50_ms(),
        stats.virtual_latency.p95_ms(),
        stats.virtual_latency.mean_ms(),
        stats.virtual_latency.std_ms()
    );
    println!(
        "energy: {:.2} mJ/request (fleet total {:.2} J)",
        stats.total_energy_j / n as f64 * 1e3,
        stats.total_energy_j
    );
    println!("host throughput: {:.1} req/s (wall {:.2} s)", n as f64 / wall, wall);
    println!(
        "fault counters: timeouts {}  crashes {}  re-dispatches {}  late harvests {}  \
         quorum failures {}  quorum histogram {:?}",
        stats.fault.timeouts,
        stats.fault.crashes,
        stats.fault.redispatches,
        stats.fault.harvested_late,
        stats.fault.quorum_failures,
        stats.fault.quorum_histogram()
    );
    println!(
        "replication counters: replica hits {}  promotions {}  standbys placed {}  \
         shed {}",
        stats.fault.replica_hits,
        stats.fault.promotions,
        stats.fault.replicas_placed,
        stats.fault.shed
    );
    println!(
        "elastic replication: batches full/partial/elided {}/{}/{}  mode transitions {}  \
         standby GFLOPs saved {:.2}  energy saved {:.2} mJ  fallbacks {}",
        stats.fault.batches_full,
        stats.fault.batches_partial,
        stats.fault.batches_elided,
        stats.fault.mode_transitions,
        stats.fault.standby_gflops_saved,
        stats.fault.standby_energy_saved_j * 1e3,
        stats.fault.standby_fallbacks
    );
    // per-member control plane (ISSUE 5): each member's own hysteresis
    // machine — a hot member sheds its standby while cold members keep
    // theirs, and each banks its own GFLOPs/joules
    for (m, led) in stats.fault.member_modes.iter().enumerate() {
        println!(
            "  member {m} ({}): full/partial/elided {}/{}/{}  transitions {}  \
             saved {:.2} G / {:.2} mJ",
            dep.members[m],
            led.full,
            led.partial,
            led.elided,
            led.transitions,
            led.standby_gflops_saved,
            led.standby_energy_saved_j * 1e3
        );
    }

    // --- baseline: the teacher on the strongest single device -------------
    // batch-matched comparison (the coordinator served ~16-sample batches)
    let teacher = m.model(&task.teacher)?;
    let tx2 = DeviceProfile::jetson_tx2();
    let mean_batch = (stats.requests as f64 / stats.batches.max(1) as f64).round() as usize;
    let t_out = SingleEdge::standalone(
        &tx2,
        CostModel::flops_per_sample(&teacher.arch) * mean_batch as f64,
        CostModel::memory_bytes(&teacher.arch, mean_batch),
    )?;
    println!("\n== vs single-edge teacher on TX2 (batch {mean_batch}) ==");
    println!(
        "teacher: accuracy {:.4}, latency {:.2} ms/batch, energy {:.2} mJ",
        teacher.accuracy_solo,
        t_out.total_s() * 1e3,
        t_out.total_energy_j() * 1e3
    );
    println!(
        "accuracy delta {:+.2}% (paper: <2% sacrifice at 1.7–3.1x speedup)",
        (correct as f64 / n as f64 - teacher.accuracy_solo) * 100.0
    );
    println!(
        "note: at artifact scale (~10 MFLOP models) the LAN latency floor dominates;\n\
         the paper-scale latency story (DeiT-B, 17.6 GFLOPs) is reproduced by\n\
         `cargo run --release --bin paper -- fig12`:"
    );
    // paper-scale projection with the same fleet/topology, as a Scenario
    let mut deit = coformer::model::Arch::uniform(
        coformer::model::Mode::Patch, 12, 768, 64, 12, 3072, 1000);
    deit.img_size = 224;
    deit.patch_size = 16;
    let subs: Vec<coformer::model::Arch> = [(12usize, 192usize, 3usize, 768usize),
        (12, 320, 5, 1280), (12, 256, 4, 1024)]
        .iter()
        .map(|&(l, d, h, dm)| {
            coformer::model::policy::SubModelCfg { layers: l, dim: d, heads: h, mlp_dim: dm }
                .to_arch(&deit)
        })
        .collect();
    let paper_scale = Scenario::builder()
        .fleet(DeviceProfile::paper_fleet())
        .topology(coformer::net::Topology::star(3, coformer::net::Link::mbps(100.0), 1))
        .archs(subs)
        .d_i(512)
        .replicas(2)
        .min_quorum(2)
        .build()?;
    let cof = CoFormer.run(&paper_scale)?;
    let single = SingleEdge::standalone(&tx2, CostModel::flops_per_sample(&deit), 3 << 30)?;
    println!(
        "paper-scale: DeiT-B on TX2 {:.1} ms vs CoFormer 3-dev {:.1} ms → {:.2}x speedup",
        single.total_s() * 1e3,
        cof.total_s() * 1e3,
        single.total_s() / cof.total_s()
    );
    // the elastic availability/throughput trade at the same paper scale:
    // what the coordinator's per-batch mode decision is choosing between —
    // one sweep over the dispatch-mode axis (ISSUE 4)
    let points = Sweep::new(paper_scale)
        .dispatch_modes(&[DispatchMode::Full, DispatchMode::Elided])
        .run_named(&["coformer_elastic"])?;
    let (rep, eli) = (&points[0].outcome, &points[1].outcome);
    println!(
        "elastic trade (healthy fleet): always-replicate {:.1} ms / {:.1} mJ vs \
         primaries-only {:.1} ms / {:.1} mJ ({:.1} standby GFLOPs saved per inference; \
         run `cargo run --release --bin paper -- elastic` for the fault scenarios)",
        rep.total_s() * 1e3,
        rep.total_energy_j() * 1e3,
        eli.total_s() * 1e3,
        eli.total_energy_j() * 1e3,
        eli.replication.expect("coformer-family outcome").standby_gflops_saved
    );
    Ok(())
}
