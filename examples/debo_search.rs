//! DeBo decomposition search (Algorithm 1, lines 1–11) + the Fig. 11
//! baselines: random search and uniform decomposition.
//!
//! ```text
//! cargo run --release --example debo_search
//! ```

use coformer::debo::search::{random_search, uniform_policy};
use coformer::debo::{DeBoConfig, DeBoSearch};
use coformer::device::DeviceProfile;
use coformer::evaluator::{AccuracyProxy, LatencyModel, Objective};
use coformer::model::{policy::DeviceCaps, CostModel};
use coformer::net::{Link, Topology};
use coformer::runtime::Engine;
use coformer::Result;

fn main() -> Result<()> {
    let engine = Engine::load("artifacts")?;
    let teacher = engine.manifest().model("teacher_edgenet")?.arch.clone();
    let devices = DeviceProfile::paper_fleet();
    let topo = Topology::star(3, Link::mbps(100.0), 1);
    // Fig-13-style compute cap: each device gets ≤ 50% of the teacher's FLOPs
    let caps: Vec<DeviceCaps> = devices
        .iter()
        .map(|d| DeviceCaps {
            max_flops: CostModel::flops_per_sample(&teacher) * 0.5,
            max_memory: d.memory_bytes,
        })
        .collect();
    // accuracy proxy calibrated from the build-time proxy points (Fig. 16b)
    let proxy = AccuracyProxy::fit(&engine.manifest().proxy_points);
    let obj = Objective {
        latency: LatencyModel {
            devices: &devices,
            topology: &topo,
            predictors: None,
            d_i: engine.manifest().d_i,
            agg_rows: teacher.groups,
        },
        accuracy: proxy,
        teacher: &teacher,
        caps: &caps,
        delta: 20.0,
        batch: 1,
    };

    let search = DeBoSearch::new(DeBoConfig {
        init_policies: 8,
        iterations: 32,
        candidates: 256,
        seed: 0,
        ..Default::default()
    });
    let res = search.run(&obj, 3)?;
    println!("DeBo: {} evaluations, best Ψ = {:.4}", res.evaluated, res.best_psi);
    for (i, s) in res.best.subs.iter().enumerate() {
        println!(
            "  device {} ({}): l={} d={} h={} D={}",
            i, devices[i].name, s.layers, s.dim, s.heads, s.mlp_dim
        );
    }
    let b = obj.latency.breakdown(&res.best, &teacher);
    println!(
        "predicted: latency {:.2} ms (compute {:?} ms), loss proxy {:.3}",
        b.total_s * 1e3,
        b.compute_s.iter().map(|s| (s * 1e5).round() / 100.0).collect::<Vec<_>>(),
        obj.accuracy.policy_loss(&res.best)
    );

    // baselines
    let rand = random_search(&obj, 3, res.evaluated, 42)?;
    let uni = uniform_policy(&teacher, 3);
    println!("random search best Ψ = {:.4}", rand.best_psi);
    println!(
        "uniform decomposition Ψ = {:.4} (latency {:.2} ms)",
        obj.evaluate(&uni).unwrap(),
        obj.latency.breakdown(&uni, &teacher).total_s * 1e3
    );
    Ok(())
}
